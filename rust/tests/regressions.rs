//! Regression tests for dispatcher protocol bugs fixed in PR 1.

use fasgd::config::{BandwidthMode, ExperimentConfig, Policy};
use fasgd::data::synthetic;
use fasgd::experiments::common::{build_sim, fast_test_config};
use fasgd::grad::rust_mlp::{init_params, RustMlpEngine};
use fasgd::server::{build_server, UpdateEngine};
use fasgd::sim::dispatcher::{DataSource, SimParts, Simulator};

fn mlp_parts(cfg: &ExperimentConfig, val: usize, eval_mu: usize) -> SimParts {
    let sizes = vec![784, cfg.mlp_hidden, 10];
    let init = init_params(cfg.seed, &sizes);
    let split = synthetic::generate(cfg.seed, 64, val, 0.3);
    SimParts {
        server: build_server(cfg, init, UpdateEngine::Rust).unwrap(),
        grad: Box::new(RustMlpEngine::new(sizes.clone(), cfg.batch)),
        eval: Box::new(RustMlpEngine::new(sizes, eval_mu)),
        data: DataSource::Classif(split),
    }
}

#[test]
fn short_val_set_eval_is_not_zeroed() {
    // Regression: with a validation set smaller than the eval engine's
    // batch, the chunk loop broke out before evaluating anything but still
    // divided by the planned chunk count — reporting val_loss = 0.0 and
    // val_acc = 0.0 (a fake converged curve). The eval must wrap indices
    // and report a real, finite loss (≈ ln 10 for an untrained model).
    let mut cfg = fast_test_config(Policy::Asgd);
    cfg.iters = 0; // run() evaluates at t=0 and at the end
    let parts = mlp_parts(&cfg, 5, 8); // val=5 < eval batch=8
    let summary = Simulator::new(cfg, parts).unwrap().run().unwrap();
    let p = summary.history.evals.first().unwrap();
    assert!(
        p.val_loss > 0.5 && p.val_loss.is_finite(),
        "short val set must produce a real loss, got {}",
        p.val_loss
    );
    assert!((0.0..=1.0).contains(&p.val_acc));
}

#[test]
fn non_divisible_val_set_uses_full_chunks() {
    // val=20 with batch 8: two full chunks (16 examples), mean over the
    // chunks actually evaluated — same answer the seed code produced when
    // it worked, now guaranteed by construction.
    let mut cfg = fast_test_config(Policy::Asgd);
    cfg.iters = 0;
    let parts = mlp_parts(&cfg, 20, 8);
    let summary = Simulator::new(cfg, parts).unwrap().run().unwrap();
    let p = summary.history.evals.first().unwrap();
    assert!(p.val_loss > 0.5 && p.val_loss.is_finite(), "{}", p.val_loss);
}

#[test]
fn sync_with_gating_rejected_at_build() {
    // Regression: policy=sync + a gating bandwidth mode deadlocks the
    // scheduler (a dropped push parks the client at the barrier forever);
    // the config must be rejected before a simulator exists.
    let mut cfg = fast_test_config(Policy::Sync);
    cfg.bandwidth = BandwidthMode::Fixed { k_push: 2, k_fetch: 1 };
    let err = build_sim(&cfg).unwrap_err();
    assert!(
        format!("{err:#}").contains("deadlock"),
        "error should explain the deadlock: {err:#}"
    );
}

#[test]
fn sync_with_gating_rejected_for_hand_assembled_sims() {
    // The same guard holds when a simulator is assembled from parts,
    // bypassing the experiment launcher.
    let mut cfg = fast_test_config(Policy::Sync);
    cfg.bandwidth = BandwidthMode::Probabilistic {
        c_push: 1.0,
        c_fetch: 0.0,
        eps: 1e-8,
    };
    let parts = mlp_parts(&cfg, 32, 8);
    assert!(Simulator::new(cfg, parts).is_err());
}
