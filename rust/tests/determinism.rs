//! FRED's headline property (paper §3): simulations are deterministic —
//! "runs which should be bitwise equivalent are bitwise equivalent".

use fasgd::config::{BandwidthMode, Policy, PushDropMode, SelectionRule};
use fasgd::experiments::common::{fast_test_config, run_experiment};

fn curve(cfg: &fasgd::config::ExperimentConfig) -> Vec<(u64, f64, f64)> {
    let s = run_experiment(cfg).unwrap();
    s.history
        .evals
        .iter()
        .map(|p| (p.iter, p.val_loss, p.val_acc))
        .collect()
}

#[test]
fn same_seed_bitwise_equal_all_policies() {
    for policy in [
        Policy::Sync,
        Policy::Asgd,
        Policy::Sasgd,
        Policy::Exponential,
        Policy::Fasgd,
    ] {
        let cfg = fast_test_config(policy.clone());
        let a = curve(&cfg);
        let b = curve(&cfg);
        assert_eq!(a, b, "{policy:?} not deterministic");
    }
}

#[test]
fn different_seed_differs() {
    let mut cfg = fast_test_config(Policy::Fasgd);
    let a = curve(&cfg);
    cfg.seed = 43;
    let b = curve(&cfg);
    assert_ne!(a, b);
}

#[test]
fn deterministic_under_bandwidth_gating() {
    for push_drop in [
        PushDropMode::ReapplyCached,
        PushDropMode::Accumulate,
        PushDropMode::Skip,
    ] {
        let mut cfg = fast_test_config(Policy::Fasgd);
        cfg.bandwidth = BandwidthMode::Probabilistic {
            c_push: 0.2,
            c_fetch: 0.4,
            eps: 1e-8,
        };
        cfg.push_drop = push_drop;
        let a = curve(&cfg);
        let b = curve(&cfg);
        assert_eq!(a, b, "{push_drop:?} not deterministic");
    }
}

#[test]
fn deterministic_under_selection_rules() {
    for rule in [
        SelectionRule::Heterogeneous { sigma: 1.0 },
        SelectionRule::Cooldown { factor: 0.3, recovery: 1.5 },
    ] {
        let mut cfg = fast_test_config(Policy::Sasgd);
        cfg.selection = rule.clone();
        let a = curve(&cfg);
        let b = curve(&cfg);
        assert_eq!(a, b, "{rule:?} not deterministic");
    }
}

#[test]
fn bandwidth_report_deterministic() {
    let mut cfg = fast_test_config(Policy::Fasgd);
    cfg.bandwidth = BandwidthMode::Probabilistic {
        c_push: 0.0,
        c_fetch: 0.5,
        eps: 1e-8,
    };
    let a = run_experiment(&cfg).unwrap().bandwidth;
    let b = run_experiment(&cfg).unwrap().bandwidth;
    assert_eq!(a, b);
}
