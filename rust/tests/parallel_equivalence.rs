//! The parallel dispatcher's contract: a worker-pool run is **bitwise
//! identical** to a serial run of the same config — every eval point,
//! every staleness count, every bandwidth decision, and the final
//! parameter vector.

use fasgd::config::{BandwidthMode, DelayConfig, DelayModel,
                    ExperimentConfig, Policy, SelectionRule};
use fasgd::experiments::common::{build_parallel_sim, build_sim,
                                 fast_test_config};
use fasgd::metrics::RunSummary;

fn small_cfg(policy: Policy, seed: u64) -> ExperimentConfig {
    let mut cfg = fast_test_config(policy);
    cfg.seed = seed;
    cfg.clients = 5;
    cfg.iters = 300;
    cfg.eval_every = 40;
    cfg
}

/// Everything in a summary that must match bitwise (wall time excluded).
fn fingerprint(s: &RunSummary) -> String {
    let mut out = String::new();
    for p in &s.history.evals {
        out.push_str(&format!(
            "eval {} {} {:?} {:?} {:?}\n",
            p.iter,
            p.server_ts,
            p.vtime.to_bits(),
            p.val_loss.to_bits(),
            p.val_acc.to_bits()
        ));
    }
    for (i, e) in &s.history.train_curve {
        out.push_str(&format!("train {} {:?}\n", i, e.to_bits()));
    }
    out.push_str(&format!("vsecs {:?}\n", s.virtual_secs.to_bits()));
    out.push_str(&format!(
        "updates {} staleness {} {} {} bw {} {} {} {} bytes {} {} {:?}\n",
        s.server_updates,
        s.staleness.total(),
        s.staleness.max(),
        s.staleness.mean().to_bits(),
        s.bandwidth.push_copies,
        s.bandwidth.push_potential,
        s.bandwidth.fetch_copies,
        s.bandwidth.fetch_potential,
        s.bandwidth.push_bytes,
        s.bandwidth.fetch_bytes,
        s.bandwidth.shard_bytes
    ));
    out
}

fn assert_equivalent(cfg: &ExperimentConfig, workers: usize) {
    let serial = build_sim(cfg).unwrap().run().unwrap();
    let parallel =
        build_parallel_sim(cfg, workers).unwrap().run().unwrap();
    assert_eq!(
        fingerprint(&serial),
        fingerprint(&parallel),
        "serial != parallel for {} (policy {:?}, seed {}, bw {:?})",
        cfg.name,
        cfg.policy,
        cfg.seed,
        cfg.bandwidth
    );
}

#[test]
fn bitwise_equal_across_seeds_policies_and_gating() {
    // ≥ 3 seeds × {fasgd, asgd, sasgd} × {always, gated}. The
    // probabilistic (eq. 9) gate needs the server's v statistics, so it
    // pairs with fasgd only; the statistics-free policies take the Dean'12
    // fixed-period gate (validate() rejects the old silent pairing).
    for seed in [7u64, 21, 1234] {
        for policy in [Policy::Fasgd, Policy::Asgd, Policy::Sasgd] {
            let gated = if policy == Policy::Fasgd {
                BandwidthMode::Probabilistic {
                    c_push: 0.3,
                    c_fetch: 0.6,
                    eps: 1e-8,
                }
            } else {
                BandwidthMode::Fixed { k_push: 2, k_fetch: 3 }
            };
            for bandwidth in [BandwidthMode::Always, gated] {
                let mut cfg = small_cfg(policy.clone(), seed);
                cfg.bandwidth = bandwidth;
                assert_equivalent(&cfg, 3);
            }
        }
    }
}

#[test]
fn bitwise_equal_fixed_period_gating() {
    let mut cfg = small_cfg(Policy::Fasgd, 5);
    cfg.bandwidth = BandwidthMode::Fixed { k_push: 2, k_fetch: 3 };
    assert_equivalent(&cfg, 4);
}

#[test]
fn bitwise_equal_sync_policy() {
    // Sync exercises the barrier replay in the schedule planner.
    let mut cfg = small_cfg(Policy::Sync, 11);
    cfg.clients = 4;
    cfg.iters = 240;
    assert_equivalent(&cfg, 4);
    // Lookahead smaller than λ forces windows to split barrier cycles.
    cfg.lookahead = 2;
    assert_equivalent(&cfg, 2);
}

#[test]
fn bitwise_equal_under_selection_rules() {
    for rule in [
        SelectionRule::Heterogeneous { sigma: 1.0 },
        SelectionRule::Cooldown { factor: 0.3, recovery: 1.5 },
    ] {
        let mut cfg = small_cfg(Policy::Asgd, 3);
        cfg.selection = rule;
        assert_equivalent(&cfg, 3);
    }
}

#[test]
fn bitwise_equal_with_probe_enabled() {
    let mut cfg = small_cfg(Policy::Fasgd, 2);
    cfg.probe_every = 25;
    let serial = build_sim(&cfg).unwrap().run().unwrap();
    let parallel = build_parallel_sim(&cfg, 3).unwrap().run().unwrap();
    assert_eq!(serial.probes.records, parallel.probes.records);
    assert_eq!(fingerprint(&serial), fingerprint(&parallel));
}

#[test]
fn final_parameters_bitwise_equal() {
    // Mid-run comparison through run_until: the parameter vectors
    // themselves must match, not just the metric curves.
    let cfg = small_cfg(Policy::Fasgd, 99);
    let mut serial = build_sim(&cfg).unwrap();
    for _ in 0..257 {
        serial.step().unwrap();
    }
    let mut parallel = build_parallel_sim(&cfg, 4).unwrap();
    parallel.run_until(257).unwrap();
    assert_eq!(parallel.iterations(), 257);
    assert_eq!(serial.server().params(), parallel.server().params());
    assert_eq!(serial.server().timestamp(), parallel.server().timestamp());
}

#[test]
fn builder_facade_preserves_bitwise_equivalence() {
    // The public SimulationBuilder front door must uphold the same
    // serial-vs-parallel contract as the raw constructors — including for
    // the registry-added gap_aware policy.
    use fasgd::sim::Simulation;
    for policy in [Policy::Fasgd, Policy::GapAware] {
        let mut cfg = small_cfg(policy, 17);
        cfg.lookahead = 8;
        let serial = Simulation::builder(cfg.clone())
            .workers(1)
            .build()
            .unwrap()
            .run()
            .unwrap();
        let parallel = Simulation::builder(cfg.clone())
            .workers(4)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(
            fingerprint(&serial),
            fingerprint(&parallel),
            "builder serial != builder parallel for {:?}",
            cfg.policy
        );
    }
}

#[test]
fn lookahead_and_worker_count_do_not_change_results() {
    let base = {
        let cfg = small_cfg(Policy::Asgd, 31);
        build_sim(&cfg).unwrap().run().unwrap()
    };
    for (workers, lookahead) in [(2, 1), (2, 64), (6, 4), (8, 32)] {
        let mut cfg = small_cfg(Policy::Asgd, 31);
        cfg.lookahead = lookahead;
        let s = build_parallel_sim(&cfg, workers).unwrap().run().unwrap();
        assert_eq!(
            fingerprint(&base),
            fingerprint(&s),
            "workers={workers} lookahead={lookahead}"
        );
    }
}

#[test]
fn pipelined_matrix_policies_selection_inflight() {
    // The pipelined speculative dispatcher over every registered policy ×
    // every selection rule × in-flight depths {1, 2×workers, deep}. One
    // serial baseline per (policy, rule); every pipelined run must match
    // it bitwise.
    let workers = 4;
    for policy in [
        Policy::Sync,
        Policy::Asgd,
        Policy::Sasgd,
        Policy::Exponential,
        Policy::Fasgd,
        Policy::GapAware,
    ] {
        for rule in [
            SelectionRule::Uniform,
            SelectionRule::Heterogeneous { sigma: 1.0 },
            SelectionRule::Cooldown { factor: 0.3, recovery: 1.5 },
        ] {
            let mut cfg = small_cfg(policy.clone(), 13);
            cfg.iters = 200;
            cfg.eval_every = 50;
            cfg.selection = rule.clone();
            let serial = build_sim(&cfg).unwrap().run().unwrap();
            let want = fingerprint(&serial);
            for inflight in [1usize, 2 * workers, 64] {
                cfg.inflight = inflight;
                let parallel = build_parallel_sim(&cfg, workers)
                    .unwrap()
                    .run()
                    .unwrap();
                assert_eq!(
                    want,
                    fingerprint(&parallel),
                    "pipelined != serial for policy {:?} rule {rule:?} \
                     inflight {inflight}",
                    cfg.policy
                );
            }
        }
    }
}

#[test]
fn pipelined_matches_windowed_legacy_mode() {
    // `pipeline = false` keeps the PR-1 windowed fan-out/fan-in loop
    // alive for A/B benchmarks; it must stay bitwise-equivalent too.
    for policy in [Policy::Fasgd, Policy::Sync] {
        let mut cfg = small_cfg(policy, 23);
        let serial = build_sim(&cfg).unwrap().run().unwrap();
        cfg.pipeline = false;
        let windowed =
            build_parallel_sim(&cfg, 4).unwrap().run().unwrap();
        assert_eq!(
            fingerprint(&serial),
            fingerprint(&windowed),
            "windowed legacy mode diverged for {:?}",
            cfg.policy
        );
    }
}

#[test]
fn speculation_miss_recomputes_from_fresh_snapshot() {
    // Force epoch invalidations: fixed-period gating with k_fetch = 1
    // transmits every fetch (so every apply replaces the fetching
    // client's θ and bumps its epoch) while keeping bandwidth mode
    // non-`always`, which makes the dispatcher speculate eagerly on
    // repeat clients instead of deferring them. With λ=4 and a deep
    // in-flight window, repeats land in flight constantly, so stale
    // snapshots MUST be detected and recomputed — and the applied
    // gradients must come from the fresh snapshots, or the parameter
    // vector diverges from serial immediately.
    let mut cfg = small_cfg(Policy::Fasgd, 41);
    cfg.clients = 4;
    cfg.iters = 250;
    cfg.bandwidth = BandwidthMode::Fixed { k_push: 1, k_fetch: 1 };
    cfg.inflight = 16;

    let mut serial = build_sim(&cfg).unwrap();
    serial.run_until(250).unwrap();

    let mut parallel = build_parallel_sim(&cfg, 4).unwrap();
    parallel.run_until(250).unwrap();

    let spec = parallel.speculation();
    assert!(
        spec.recomputed > 0,
        "expected forced speculation misses, got {spec:?}"
    );
    // Gated mode speculates every pick (no deferrals); recomputes are
    // counted separately from first submissions.
    assert_eq!(spec.submitted, 250, "{spec:?}");
    assert_eq!(spec.deferred, 0, "{spec:?}");
    assert_eq!(
        serial.server().params(),
        parallel.server().params(),
        "a stale-snapshot gradient reached the server"
    );
    assert_eq!(serial.server().timestamp(), parallel.server().timestamp());
}

fn delay_matrix() -> Vec<(&'static str, DelayConfig)> {
    vec![
        (
            "bimodal_compute",
            DelayConfig {
                compute: DelayModel::Bimodal {
                    straggler_frac: 0.25,
                    slow_mult: 6.0,
                },
                network: DelayModel::None,
            },
        ),
        (
            "lognormal_both",
            DelayConfig {
                compute: DelayModel::LogNormal { mu: 0.0, sigma: 0.8 },
                network: DelayModel::LogNormal { mu: -1.0, sigma: 0.4 },
            },
        ),
        (
            "bimodal_net_lognormal_compute",
            DelayConfig {
                compute: DelayModel::LogNormal { mu: -0.5, sigma: 0.5 },
                network: DelayModel::Bimodal {
                    straggler_frac: 0.5,
                    slow_mult: 3.0,
                },
            },
        ),
    ]
}

#[test]
fn pipelined_matrix_delay_models_inflight() {
    // The acceptance bar: with any delay model enabled, `--workers N` is
    // bitwise identical to `--workers 1` — over the delay-model matrix ×
    // in-flight depths {1, 2×workers, 64}, for an async, a
    // staleness-aware, and the barrier policy.
    let workers = 4;
    for policy in [Policy::Asgd, Policy::Fasgd, Policy::Sync] {
        for (name, delay) in delay_matrix() {
            let mut cfg = small_cfg(policy.clone(), 61);
            cfg.iters = 200;
            cfg.eval_every = 50;
            cfg.delay = delay;
            cfg.eval_every_vsecs = 40.0; // virtual-time cadence in play too
            let serial = build_sim(&cfg).unwrap().run().unwrap();
            let want = fingerprint(&serial);
            for inflight in [1usize, 2 * workers, 64] {
                cfg.inflight = inflight;
                let parallel = build_parallel_sim(&cfg, workers)
                    .unwrap()
                    .run()
                    .unwrap();
                assert_eq!(
                    want,
                    fingerprint(&parallel),
                    "delay {name}: pipelined != serial for policy {:?} \
                     inflight {inflight}",
                    cfg.policy
                );
            }
            // The legacy windowed loop must uphold the contract under
            // delays too (repeat cuts are frequent in completion order).
            cfg.inflight = 0;
            cfg.pipeline = false;
            let windowed =
                build_parallel_sim(&cfg, workers).unwrap().run().unwrap();
            assert_eq!(
                want,
                fingerprint(&windowed),
                "delay {name}: windowed != serial for policy {:?}",
                cfg.policy
            );
            // Delay-enabled runs must report real virtual time (not the
            // degenerate 1.0/iteration clock).
            assert!(serial.virtual_secs > 0.0);
            assert!(
                (serial.virtual_secs - serial.iters as f64).abs() > 1e-9,
                "delay {name}: vsecs suspiciously equals iteration count"
            );
        }
    }
}

#[test]
fn delays_with_forced_speculation_misses_stay_bitwise_equal() {
    // Fixed k_fetch = 1 gating makes every apply replace the fetching
    // client's θ (eager speculation, never deferral), so with λ=4 and a
    // deep in-flight window the pipelined dispatcher must hit stale
    // θ-epochs and recompute — while the virtual clock is driving
    // completion order. Misses must not perturb timestamps or results.
    let mut cfg = small_cfg(Policy::Fasgd, 67);
    cfg.clients = 4;
    cfg.iters = 250;
    cfg.bandwidth = BandwidthMode::Fixed { k_push: 1, k_fetch: 1 };
    cfg.inflight = 16;
    cfg.delay.compute = DelayModel::Bimodal {
        straggler_frac: 0.25,
        slow_mult: 5.0,
    };
    cfg.delay.network = DelayModel::LogNormal { mu: -1.5, sigma: 0.3 };

    let mut serial = build_sim(&cfg).unwrap();
    serial.run_until(250).unwrap();

    let mut parallel = build_parallel_sim(&cfg, 4).unwrap();
    parallel.run_until(250).unwrap();

    let spec = parallel.speculation();
    assert!(
        spec.recomputed > 0,
        "expected forced speculation misses under delays, got {spec:?}"
    );
    assert_eq!(spec.deferred, 0, "gated mode never defers: {spec:?}");
    assert_eq!(
        serial.server().params(),
        parallel.server().params(),
        "a stale-snapshot gradient reached the server under delays"
    );
    assert_eq!(serial.server().timestamp(), parallel.server().timestamp());
    assert_eq!(
        serial.virtual_secs().to_bits(),
        parallel.virtual_secs().to_bits(),
        "virtual clock diverged across recomputes"
    );
}

#[test]
fn always_mode_defers_instead_of_missing() {
    // Under bandwidth `always` every fetch replaces θ, so repeat
    // speculation can never hit; the dispatcher must park repeats behind
    // their predecessor (deferral) rather than burn recomputes.
    let mut cfg = small_cfg(Policy::Asgd, 47);
    cfg.clients = 3; // small λ ⇒ repeats in flight constantly
    cfg.inflight = 12;

    let mut serial = build_sim(&cfg).unwrap();
    serial.run_until(cfg.iters).unwrap();

    let mut parallel = build_parallel_sim(&cfg, 4).unwrap();
    parallel.run_until(cfg.iters).unwrap();
    let spec = parallel.speculation();
    assert_eq!(spec.recomputed, 0, "guaranteed misses must be deferred");
    assert!(spec.deferred > 0, "λ=3 with inflight 12 must defer: {spec:?}");
    assert_eq!(serial.server().params(), parallel.server().params());
    assert_eq!(serial.server().timestamp(), parallel.server().timestamp());
}
