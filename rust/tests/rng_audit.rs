//! The dynamic half of the determinism contract: the RNG draw ledger
//! (`rng::ledger`) and the serial-vs-parallel `--rng-audit` diff.
//!
//! Acceptance bar (ISSUE 6): a fixed-seed audit produces **identical**
//! draw ledgers for `--workers 1` vs the pipelined parallel dispatcher
//! across the delay-model × shards matrix, and an injected out-of-order
//! draw is reported with the diverging `(stream, call_site)`.

use fasgd::config::{
    BandwidthMode, DelayConfig, DelayModel, ExperimentConfig, Policy,
};
use fasgd::experiments::audit::run_rng_audit;
use fasgd::experiments::common::fast_test_config;
use fasgd::metrics::RunSummary;
use fasgd::rng::ledger::{self, DrawLedger};
use fasgd::rng::Xoshiro256pp;
use fasgd::sim::Simulation;

// ---------------------------------------------------------------------
// Ledger-diff unit surface: injected out-of-order draw.
// ---------------------------------------------------------------------

// Two helper fns = two distinct call sites in this file: the draws they
// make are attributed (track_caller) to the lines inside these bodies.
fn draw_site_a(r: &mut Xoshiro256pp) -> u64 {
    r.below(1 << 20)
}

fn draw_site_b(r: &mut Xoshiro256pp) -> f64 {
    r.f64()
}

#[test]
fn injected_out_of_order_draw_names_stream_and_site() {
    // "Serial" discipline: a, a, b on the dispatcher stream.
    ledger::begin();
    let mut r = fasgd::rng::stream(7, "dispatcher", 0);
    draw_site_a(&mut r);
    draw_site_a(&mut r);
    draw_site_b(&mut r);
    let serial = ledger::end();

    // "Parallel" leg with one draw moved ahead: a, b, a.
    ledger::begin();
    let mut r = fasgd::rng::stream(7, "dispatcher", 0);
    draw_site_a(&mut r);
    draw_site_b(&mut r);
    draw_site_a(&mut r);
    let parallel = ledger::end();

    let d = ledger::diff(&serial, &parallel).expect("must diverge");
    // The auditor names the stream...
    assert_eq!(d.stream, ("dispatcher".to_string(), 0));
    // ...and the first diverging run: serial coalesced site_a x2, the
    // reordered leg only x1 before site_b cut in.
    assert_eq!(d.position, 0);
    assert_eq!(d.left.map(|run| run.count), Some(2));
    assert_eq!(d.right.map(|run| run.count), Some(1));
    // The rendered report points at this file's call site.
    let msg = d.to_string();
    assert!(msg.contains("dispatcher"), "{msg}");
    assert!(msg.contains("rng_audit.rs"), "{msg}");
}

#[test]
fn per_stream_ledgers_ignore_cross_stream_interleaving() {
    // The pipelined dispatcher legitimately reorders draws ACROSS
    // streams; the ledger must not see that as divergence.
    ledger::begin();
    let mut a = fasgd::rng::stream(7, "bandwidth", 0);
    let mut b = fasgd::rng::stream(7, "client-sampler", 3);
    draw_site_a(&mut a);
    draw_site_b(&mut b);
    draw_site_a(&mut a);
    let serial = ledger::end();

    ledger::begin();
    let mut a = fasgd::rng::stream(7, "bandwidth", 0);
    let mut b = fasgd::rng::stream(7, "client-sampler", 3);
    draw_site_b(&mut b); // batch drawn at plan time, ahead of gating
    draw_site_a(&mut a);
    draw_site_a(&mut a);
    let parallel = ledger::end();

    assert_eq!(ledger::diff(&serial, &parallel), None);
}

// ---------------------------------------------------------------------
// Full-simulator matrix: serial vs pipelined parallel.
// ---------------------------------------------------------------------

fn matrix_config(delay: &str, shards: usize) -> ExperimentConfig {
    let mut cfg = fast_test_config(Policy::Fasgd);
    cfg.name = format!("audit_{delay}_{shards}");
    cfg.iters = 160;
    cfg.eval_every = 80;
    // Probabilistic gating exercises the "bandwidth" stream per
    // (client, shard, direction); FASGD supplies the v statistics.
    cfg.bandwidth = BandwidthMode::Probabilistic {
        c_push: 0.3,
        c_fetch: 0.3,
        eps: 1e-8,
    };
    cfg.shards.count = shards;
    cfg.delay = match delay {
        "lognormal" => DelayConfig {
            compute: DelayModel::LogNormal { mu: 0.0, sigma: 0.6 },
            network: DelayModel::LogNormal { mu: -1.0, sigma: 0.3 },
        },
        "bimodal" => DelayConfig {
            compute: DelayModel::Bimodal {
                straggler_frac: 0.25,
                slow_mult: 8.0,
            },
            network: DelayModel::None,
        },
        _ => DelayConfig::default(),
    };
    cfg
}

fn audited_run(mut cfg: ExperimentConfig, workers: usize) -> (RunSummary, DrawLedger) {
    cfg.workers = workers;
    ledger::begin();
    let summary = Simulation::builder(cfg)
        .build()
        .and_then(|s| s.run())
        .expect("run");
    (summary, ledger::end())
}

#[test]
fn ledgers_identical_across_delay_and_shard_matrix() {
    for delay in ["none", "lognormal", "bimodal"] {
        for shards in [1usize, 4] {
            let cfg = matrix_config(delay, shards);
            let (s_sum, s_led) = audited_run(cfg.clone(), 1);
            let (p_sum, p_led) = audited_run(cfg, 3);
            // The ledger is the fine-grained check...
            assert_eq!(
                ledger::diff(&s_led, &p_led),
                None,
                "draw ledgers diverge for delay={delay} shards={shards}:\n\
                 serial:\n{}\nparallel:\n{}",
                s_led.to_text(),
                p_led.to_text()
            );
            // ...and the bitwise contract it guards still holds.
            assert_eq!(
                s_sum.history.evals, p_sum.history.evals,
                "delay={delay} shards={shards}"
            );
            // The audit actually observed draws (guards against the
            // ledger silently not recording).
            assert!(
                s_led.total_draws() > 0 && s_led.stream_count() >= 3,
                "empty ledger for delay={delay} shards={shards}: \n{}",
                s_led.to_text()
            );
        }
    }
}

#[test]
fn run_rng_audit_end_to_end_passes() {
    let mut cfg = matrix_config("lognormal", 4);
    cfg.workers = 3;
    let report = run_rng_audit(&cfg).expect("audit runs");
    assert!(report.passed(), "{}", report.render());
    assert_eq!(report.workers, 3);
    assert!(report.serial.total_draws() > 0);
    assert_eq!(report.serial_loss, report.parallel_loss);
    assert!(report.render().contains("PASS"));
}

#[test]
fn normal_runs_record_nothing() {
    // No begin(): streams carry no tag, training pays one branch and the
    // ledger stays empty.
    let cfg = matrix_config("none", 1);
    let _ = fasgd::experiments::common::run_experiment(&cfg).expect("run");
    ledger::begin();
    assert_eq!(ledger::end().total_draws(), 0);
}
