//! Cross-validation of the whole AOT pipeline: the PJRT-executed JAX/Pallas
//! graphs must numerically agree with the independent pure-rust
//! implementations on identical inputs. Skips (passes trivially) when
//! `artifacts/` has not been built.

use fasgd::data::synthetic;
use fasgd::experiments::common::shared_engine;
use fasgd::grad::{Batch, EvalEngine, GradientEngine, RustMlpEngine,
                  XlaEvalEngine, XlaGradEngine, XlaUpdateEngine};
use fasgd::tensor::{allclose, FasgdHparams};

fn artifacts_present() -> bool {
    fasgd::util::artifacts_dir().join("manifest.json").exists()
}

#[test]
fn xla_grad_matches_rust_grad() {
    if !artifacts_present() {
        return;
    }
    let engine = shared_engine().unwrap();
    let theta = engine.registry().load_init("mlp").unwrap();
    let mut xla = XlaGradEngine::new(&engine, "mlp", 8).unwrap();
    let mut rust = RustMlpEngine::paper(8);
    assert_eq!(xla.param_count(), rust.param_count());

    let split = synthetic::generate(3, 64, 0, 0.35);
    for chunk in 0..3 {
        let idx: Vec<usize> = (chunk * 8..(chunk + 1) * 8).collect();
        let (x, y) = split.train.gather(&idx);
        let batch = Batch::Classif { x: &x, y: &y };
        let mut gx = vec![0.0f32; xla.param_count()];
        let mut gr = vec![0.0f32; rust.param_count()];
        let lx = xla.grad(&theta, &batch, &mut gx).unwrap();
        let lr = rust.grad(&theta, &batch, &mut gr).unwrap();
        assert!(
            (lx - lr).abs() < 1e-4,
            "loss mismatch: xla {lx} rust {lr}"
        );
        assert!(
            allclose(&gx, &gr, 1e-3, 1e-5),
            "gradient mismatch (max abs diff {})",
            fasgd::tensor::max_abs_diff(&gx, &gr)
        );
    }
}

#[test]
fn xla_eval_matches_rust_eval() {
    if !artifacts_present() {
        return;
    }
    let engine = shared_engine().unwrap();
    let theta = engine.registry().load_init("mlp").unwrap();
    let mut xla = XlaEvalEngine::new(&engine, "mlp").unwrap();
    let b = xla.batch_size();
    let mut rust = RustMlpEngine::new(vec![784, 200, 10], b);
    let split = synthetic::generate(5, b, 0, 0.35);
    let idx: Vec<usize> = (0..b).collect();
    let (x, y) = split.train.gather(&idx);
    let batch = Batch::Classif { x: &x, y: &y };
    let (lx, ax) = xla.eval(&theta, &batch).unwrap();
    let (lr, ar) = rust.eval(&theta, &batch).unwrap();
    assert!((lx - lr).abs() < 1e-4, "{lx} vs {lr}");
    assert!((ax - ar).abs() < 1e-6, "{ax} vs {ar}");
}

#[test]
fn xla_fasgd_update_matches_rust_fused() {
    if !artifacts_present() {
        return;
    }
    let engine = shared_engine().unwrap();
    let p = 159_010;
    for inverse in [false, true] {
        let hp = FasgdHparams { inverse_variant: inverse, ..Default::default() };
        let upd = XlaUpdateEngine::new(&engine, p, &hp).unwrap();
        let mut rng = fasgd::rng::stream(7, "roundtrip", inverse as u64);
        let theta0: Vec<f32> = (0..p).map(|_| rng.f32() - 0.5).collect();
        let n0: Vec<f32> = (0..p).map(|_| rng.f32()).collect();
        let b0: Vec<f32> = (0..p).map(|_| rng.f32() * 0.1).collect();
        let v0: Vec<f32> = (0..p).map(|_| rng.f32() + 0.05).collect();
        let g: Vec<f32> = (0..p).map(|_| rng.f32() - 0.5).collect();

        let (mut tx, mut nx, mut bx, mut vx) =
            (theta0.clone(), n0.clone(), b0.clone(), v0.clone());
        let vmean_x = upd.apply(&mut tx, &mut nx, &mut bx, &mut vx, &g, 0.01)
            .unwrap();

        let (mut tr, mut nr, mut br, mut vr) = (theta0, n0, b0, v0);
        let vmean_r = fasgd::tensor::fasgd_update_fused(
            &mut tr, &mut nr, &mut br, &mut vr, &g, 0.01, &hp);

        let (rtol, atol) = if inverse { (2e-3, 1e-4) } else { (1e-4, 1e-5) };
        assert!(allclose(&tx, &tr, rtol, atol), "theta (inverse={inverse})");
        assert!(allclose(&vx, &vr, rtol, atol), "v (inverse={inverse})");
        assert!(
            (vmean_x - vmean_r).abs() < 1e-4,
            "v_mean {vmean_x} vs {vmean_r}"
        );
    }
}

#[test]
fn init_bin_is_glorot_shaped() {
    if !artifacts_present() {
        return;
    }
    let engine = shared_engine().unwrap();
    let theta = engine.registry().load_init("mlp").unwrap();
    assert_eq!(theta.len(), 159_010);
    // w1 block: Glorot-uniform limit sqrt(6/984) ≈ 0.0781
    let w1 = &theta[..784 * 200];
    let limit = (6.0f64 / (784.0 + 200.0)).sqrt() as f32;
    assert!(w1.iter().all(|&w| w.abs() <= limit * 1.001));
    assert!(w1.iter().any(|&w| w.abs() > limit * 0.9));
    // biases zero
    let b1 = &theta[784 * 200..784 * 200 + 200];
    assert!(b1.iter().all(|&b| b == 0.0));
}

#[test]
fn transformer_artifacts_run_and_learn_signal() {
    if !artifacts_present() {
        return;
    }
    let engine = shared_engine().unwrap();
    let theta = engine.registry().load_init("transformer_tiny").unwrap();
    let mut ge = XlaGradEngine::new(&engine, "transformer_tiny", 8).unwrap();
    let corpus = fasgd::data::corpus::generate(0, 64, 5_000);
    let mut sampler =
        fasgd::data::sampler::WindowSampler::new(0, 0, &corpus, 32, 8);
    let (mut toks, mut tgts) = (Vec::new(), Vec::new());
    sampler.next_batch(&corpus, &mut toks, &mut tgts);
    let mut grad = vec![0.0f32; ge.param_count()];
    let loss = ge
        .grad(&theta, &Batch::Lm { tokens: &toks, targets: &tgts }, &mut grad)
        .unwrap();
    // fresh init ⇒ near-uniform prediction ⇒ loss ≈ ln(64) (the random
    // head adds a few tenths of a nat on the tiny config)
    assert!((loss - 64f32.ln()).abs() < 1.0, "{loss}");
    assert!(fasgd::tensor::l2_norm(&grad) > 0.0);

    // a few SGD steps on one batch reduce the loss through the XLA path
    let mut th = theta;
    for _ in 0..5 {
        ge.grad(&th, &Batch::Lm { tokens: &toks, targets: &tgts }, &mut grad)
            .unwrap();
        fasgd::tensor::axpy(&mut th, -0.5, &grad);
    }
    let loss2 = ge
        .grad(&th, &Batch::Lm { tokens: &toks, targets: &tgts }, &mut grad)
        .unwrap();
    assert!(loss2 < loss, "{loss} -> {loss2}");
}
