// D006 positive: bare abort macros in crash-recoverable code. A host
// panic is the one failure checkpoint/requeue cannot absorb.
pub fn dispatch(kind: u8) -> u64 {
    match kind {
        0 => 1,
        1 => todo!("windowed dispatch"),
        2 => unimplemented!(),
        _ => panic!("unknown dispatch kind {kind}"),
    }
}
