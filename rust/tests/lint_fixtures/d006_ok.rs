// D006 negative: errors instead of aborts; `assert!` invariant checks
// and `std::panic` path references are not bare abort macros. Test
// modules may panic freely.
pub fn dispatch(kind: u8) -> Result<u64, String> {
    assert!(kind < 16, "caller-checked range");
    debug_assert!(kind != 9);
    match kind {
        0 => Ok(1),
        _ => Err(format!("unknown dispatch kind {kind}")),
    }
}

pub fn guarded(f: impl FnOnce() -> u64 + std::panic::UnwindSafe) -> u64 {
    std::panic::catch_unwind(f).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_is_exempt() {
        match super::dispatch(3) {
            Err(_) => {}
            other => panic!("expected error, got {other:?}"),
        }
    }
}
