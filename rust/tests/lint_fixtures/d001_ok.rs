// D001 negative: ordered collections only; "HashMap" in strings and
// comments must not trigger.
use std::collections::{BTreeMap, BTreeSet};

pub fn tally(clients: &[usize]) -> usize {
    let mut seen: BTreeSet<usize> = BTreeSet::new();
    let mut counts: BTreeMap<usize, u64> = BTreeMap::new();
    for &c in clients {
        seen.insert(c);
        *counts.entry(c).or_insert(0) += 1;
    }
    let _doc = "a HashMap would be nondeterministic here";
    seen.len()
}
