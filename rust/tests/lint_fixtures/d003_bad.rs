// D003 positive: direct rand_core use and unnamed stream construction
// outside rng/.
use rand_core::RngCore;

pub fn draw(seed: u64) -> u64 {
    let mut a = crate::rng::Xoshiro256pp::new(seed);
    let mut sm = crate::rng::SplitMix64::new(seed);
    a.next_u64() ^ sm.next_u64()
}
