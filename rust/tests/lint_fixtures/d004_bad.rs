// D004 positive: panicking accessors on the protocol/apply path.
pub fn apply(slot: Option<Vec<f32>>, ts: Option<u64>) -> (Vec<f32>, u64) {
    let g = slot.unwrap();
    let t = ts.expect("timestamp planned");
    (g, t)
}
