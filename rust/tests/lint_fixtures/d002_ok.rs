// D002 negative: virtual time only ("Instant" appears only in this
// comment and in a string, which the scanner ignores).
pub fn advance(vclock: &mut f64, dt: f64) -> f64 {
    *vclock += dt;
    let _doc = "never Instant::now() in the simulator";
    *vclock
}
