// D001 positive: unordered map/set in deterministic-core code.
use std::collections::HashMap;
use std::collections::HashSet;

pub fn tally(clients: &[usize]) -> usize {
    let mut seen: HashSet<usize> = HashSet::new();
    let mut counts: HashMap<usize, u64> = HashMap::new();
    for &c in clients {
        seen.insert(c);
        *counts.entry(c).or_insert(0) += 1;
    }
    seen.len()
}
