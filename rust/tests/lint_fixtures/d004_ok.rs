// D004 negative: fallible accessors and non-panicking combinators
// (`unwrap_or`, `map_or` must not be mistaken for `unwrap`).
pub fn apply(
    slot: Option<Vec<f32>>,
    ts: Option<u64>,
) -> Option<(Vec<f32>, u64)> {
    let g = slot?;
    let t = ts.unwrap_or(0);
    let _scaled = Some(2.0).map_or(1.0, |x| x);
    Some((g, t))
}
