// Scope fixture: serve/ joined the D001 + D004 scopes in PR 7 (the
// daemon is multi-writer shared state). Linted by lint_rules.rs with
// scope_for("serve/daemon.rs") — both rules must fire; with the cli/
// scope neither does.
use std::collections::HashMap;

pub fn lookup(runs: &HashMap<String, u32>, id: &str) -> u32 {
    *runs.get(id).unwrap()
}
