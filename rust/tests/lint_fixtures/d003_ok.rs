// D003 negative: every stream is named via the rng::stream API; passing
// a Xoshiro256pp around (without constructing one) is fine.
pub fn draw(master_seed: u64, client: u64) -> u64 {
    let mut r = crate::rng::stream(master_seed, "client-sampler", client);
    r.below(1024)
}

pub fn reuse(r: &mut crate::rng::Xoshiro256pp) -> f64 {
    r.f64()
}
