// D005 negative: the unsafe block carries a SAFETY comment directly
// above it.
pub fn ftz() {
    // SAFETY: writes only this thread's MXCSR register.
    unsafe {
        core::arch::x86_64::_mm_setcsr(0x8040);
    }
}
