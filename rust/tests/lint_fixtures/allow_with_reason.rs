// Suppression negative: lint:allow with a reason silences the finding,
// both on the line above and as a trailing same-line comment.
// lint:allow(D001, fixture demonstrating the suppression syntax)
use std::collections::HashMap;

pub fn f() -> u64 {
    let m: HashMap<u32, u32> = HashMap::new(); // lint:allow(D001, same-line suppression)
    m.len() as u64
}
