// D002 positive: wall-clock reads in simulator code.
use std::time::{Instant, SystemTime};

pub fn step_duration() -> f64 {
    let start = Instant::now();
    let _epoch = SystemTime::now();
    start.elapsed().as_secs_f64()
}
