// D005 positive: unsafe block with no SAFETY comment anywhere near it.

pub fn ftz() {
    unsafe {
        core::arch::x86_64::_mm_setcsr(0x8040);
    }
}
