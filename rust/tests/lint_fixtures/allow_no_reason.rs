// Suppression positive: a reason-less lint:allow is itself a finding
// (D000) and does NOT suppress the underlying rule.
// lint:allow(D001)
use std::collections::HashMap;

pub type T = HashMap<u32, u32>;
