//! The sharded parameter plane (PR 5): shard-tiling properties, the
//! serial↔parallel bitwise contract over shard counts × bandwidth modes ×
//! in-flight depths, per-shard byte accounting, and wire-time charging on
//! the finite-rate server link.

use fasgd::config::{BandwidthMode, ExperimentConfig, Policy, PushDropMode};
use fasgd::experiments::common::{build_parallel_sim, build_sim,
                                 fast_test_config};
use fasgd::metrics::RunSummary;
use fasgd::rng::Xoshiro256pp;
use fasgd::server::ParamStore;
use fasgd::sim::{Event, Simulation};

// ---------------------------------------------------------------------------
// ParamStore geometry: shards tile θ exactly.

#[test]
fn prop_shards_tile_theta_exactly() {
    // Randomized (p, count) pairs, plus the adversarial edges: shards
    // must cover every index exactly once, in order, with the uneven
    // tail spread over the leading shards.
    let mut rng = Xoshiro256pp::new(0x5A4D);
    let mut cases: Vec<(usize, usize)> = vec![
        (0, 1),
        (0, 7),
        (1, 1),
        (1, 5),
        (7, 7),
        (7, 8), // count > p clamps
        (10, 4),
        (159_010, 7), // the paper MLP's P, uneven
    ];
    for _ in 0..200 {
        let p = rng.below(10_000) as usize;
        let count = 1 + rng.below(64) as usize;
        cases.push((p, count));
    }
    for (p, count) in cases {
        let ps = ParamStore::new(p, count, 4);
        assert!(ps.count() >= 1 && ps.count() <= count.max(1));
        let mut next = 0usize;
        let mut sizes = Vec::new();
        for s in 0..ps.count() {
            let r = ps.range(s);
            assert_eq!(r.start, next, "gap/overlap at shard {s} (p={p})");
            next = r.end;
            sizes.push(r.len());
            assert_eq!(ps.len(s), r.len());
            assert_eq!(ps.shard_bytes(s), r.len() as u64 * 4);
        }
        assert_eq!(next, p, "shards do not cover θ (p={p}, count={count})");
        // Uneven tail: sizes differ by at most one, non-increasing.
        let (min, max) = (
            *sizes.iter().min().unwrap(),
            *sizes.iter().max().unwrap(),
        );
        assert!(max - min <= 1, "{sizes:?}");
        assert!(sizes.windows(2).all(|w| w[0] >= w[1]), "{sizes:?}");
        let total: u64 = (0..ps.count()).map(|s| ps.shard_bytes(s)).sum();
        assert_eq!(total, ps.total_bytes());
    }
}

// ---------------------------------------------------------------------------
// Bitwise serial↔parallel equality over the sharding matrix.

fn fingerprint(s: &RunSummary) -> String {
    let mut out = String::new();
    for p in &s.history.evals {
        out.push_str(&format!(
            "eval {} {} {:?} {:?} {:?}\n",
            p.iter,
            p.server_ts,
            p.vtime.to_bits(),
            p.val_loss.to_bits(),
            p.val_acc.to_bits()
        ));
    }
    out.push_str(&format!("vsecs {:?}\n", s.virtual_secs.to_bits()));
    out.push_str(&format!(
        "updates {} bw {} {} {} {} bytes {} {} shard_bytes {:?}\n",
        s.server_updates,
        s.bandwidth.push_copies,
        s.bandwidth.push_potential,
        s.bandwidth.fetch_copies,
        s.bandwidth.fetch_potential,
        s.bandwidth.push_bytes,
        s.bandwidth.fetch_bytes,
        s.bandwidth.shard_bytes
    ));
    out
}

fn sharded_cfg(shards: usize, bandwidth: BandwidthMode) -> ExperimentConfig {
    let mut cfg = fast_test_config(Policy::Fasgd);
    cfg.seed = 71;
    cfg.clients = 5;
    cfg.iters = 250;
    cfg.eval_every = 50;
    cfg.shards.count = shards;
    cfg.bandwidth = bandwidth;
    cfg
}

#[test]
fn bitwise_equal_across_shard_counts_modes_and_inflight() {
    // shards.count ∈ {1, 4, 7} × bandwidth modes × --inflight {1, 8}: the
    // per-shard gate draws happen inside complete_iteration in schedule
    // order, so the pipelined dispatcher must replay them exactly —
    // including partial (mixed-shard) pushes and fetches.
    let workers = 4;
    for shards in [1usize, 4, 7] {
        for bandwidth in [
            BandwidthMode::Always,
            BandwidthMode::Fixed { k_push: 2, k_fetch: 3 },
            BandwidthMode::Probabilistic {
                c_push: 0.3,
                c_fetch: 0.6,
                eps: 1e-8,
            },
        ] {
            let cfg = sharded_cfg(shards, bandwidth.clone());
            let serial = build_sim(&cfg).unwrap().run().unwrap();
            let want = fingerprint(&serial);
            for inflight in [1usize, 8] {
                let mut cfg = cfg.clone();
                cfg.inflight = inflight;
                let parallel = build_parallel_sim(&cfg, workers)
                    .unwrap()
                    .run()
                    .unwrap();
                assert_eq!(
                    want,
                    fingerprint(&parallel),
                    "serial != parallel for shards={shards} \
                     bw={bandwidth:?} inflight={inflight}"
                );
            }
        }
    }
}

#[test]
fn bitwise_equal_sharded_with_link_and_delays() {
    // Wire-time charging + the virtual clock + sharded gating together:
    // vnow = latency clock + cumulative wire seconds, all in schedule
    // order — both execution modes must agree on every bit.
    let mut cfg = sharded_cfg(
        4,
        BandwidthMode::Probabilistic { c_push: 0.3, c_fetch: 0.6, eps: 1e-8 },
    );
    cfg.link.rate_bytes_per_vsec = 5e5;
    cfg.delay.compute = fasgd::config::DelayModel::Bimodal {
        straggler_frac: 0.25,
        slow_mult: 4.0,
    };
    cfg.eval_every_vsecs = 25.0;
    let serial = build_sim(&cfg).unwrap().run().unwrap();
    let parallel = build_parallel_sim(&cfg, 4).unwrap().run().unwrap();
    assert_eq!(fingerprint(&serial), fingerprint(&parallel));
    // The wire charge is visible on the time axis: the same run without a
    // link rate simulates strictly fewer virtual seconds.
    let mut no_link = cfg.clone();
    no_link.link.rate_bytes_per_vsec = 0.0;
    let baseline = build_sim(&no_link).unwrap().run().unwrap();
    assert!(
        serial.virtual_secs > baseline.virtual_secs,
        "wire charges missing from the clock: {} vs {}",
        serial.virtual_secs,
        baseline.virtual_secs
    );
}

// ---------------------------------------------------------------------------
// Byte accounting and the bandwidth-vs-time axis.

#[test]
fn gated_run_moves_fewer_bytes_and_less_wire_time_than_always() {
    // The acceptance bar: a B-FASGD run shows gated bytes-on-wire <
    // `always`-mode bytes, and with a finite-rate link the saving lands
    // on the virtual-time axis (delays off ⇒ vnow = iters + wire secs).
    let rate = 2e5;
    let mk = |bandwidth| {
        let mut cfg = sharded_cfg(4, bandwidth);
        cfg.link.rate_bytes_per_vsec = rate;
        cfg
    };
    let always = build_sim(&mk(BandwidthMode::Always)).unwrap().run().unwrap();
    let gated = build_sim(&mk(BandwidthMode::Probabilistic {
        c_push: 0.5,
        c_fetch: 1.0,
        eps: 1e-8,
    }))
    .unwrap()
    .run()
    .unwrap();

    assert_eq!(
        always.bandwidth.total_bytes(),
        always.bandwidth.potential_bytes(),
        "always mode transmits everything"
    );
    assert!(
        gated.bandwidth.total_bytes() < always.bandwidth.total_bytes(),
        "gated {} !< always {}",
        gated.bandwidth.total_bytes(),
        always.bandwidth.total_bytes()
    );

    // Virtual-time cost reflects exactly the transmitted bytes.
    for s in [&always, &gated] {
        let wire = s.bandwidth.total_bytes() as f64 / rate;
        let expect = s.iters as f64 + wire;
        assert!(
            (s.virtual_secs - expect).abs() < 1e-6 * expect.max(1.0),
            "vsecs {} != iters + bytes/rate {}",
            s.virtual_secs,
            expect
        );
    }
    assert!(gated.virtual_secs < always.virtual_secs);
}

#[test]
fn partial_transmissions_show_up_in_events_and_accounting() {
    // With several shards under the probabilistic gate, opportunities
    // where some-but-not-all shards transmit must appear, their byte
    // counts must be partial, and the event stream must reconcile with
    // the report's byte totals exactly.
    let cfg = sharded_cfg(
        4,
        BandwidthMode::Probabilistic { c_push: 0.3, c_fetch: 0.6, eps: 1e-8 },
    );
    let iters = cfg.iters;
    let mut sim = Simulation::builder(cfg).trace(1 << 14).build().unwrap();
    sim.run_until(iters).unwrap();
    let events = sim.trace().events();

    let mut push_bytes = 0u64;
    let mut fetch_bytes = 0u64;
    let mut partial = 0u64;
    let mut full_copy_bytes = None;
    for e in events {
        match e {
            Event::Push { shards_tx, bytes, transmitted, .. } => {
                push_bytes += bytes;
                assert_eq!(transmitted, shards_tx > 0);
                if shards_tx == 4 {
                    full_copy_bytes = Some(bytes);
                }
                if shards_tx > 0 && shards_tx < 4 {
                    partial += 1;
                    assert!(bytes > 0);
                }
            }
            Event::Fetch { shards_tx, bytes, transmitted, .. } => {
                fetch_bytes += bytes;
                assert_eq!(transmitted, shards_tx > 0);
                if let Some(full) = full_copy_bytes {
                    if shards_tx > 0 && shards_tx < 4 {
                        assert!(bytes < full, "partial must cost < a copy");
                    }
                }
            }
            _ => {}
        }
    }
    assert!(
        partial > 0,
        "expected mixed-shard pushes under per-shard gating"
    );
    // The event stream and the accounting agree byte for byte. run() on
    // the finished sim adds only eval points (iterations are done), so
    // the byte counters are exactly what the events recorded.
    let summary = sim.run().unwrap();
    assert_eq!(summary.bandwidth.push_bytes, push_bytes);
    assert_eq!(summary.bandwidth.fetch_bytes, fetch_bytes);
    let shard_total: u64 = summary.bandwidth.shard_bytes.iter().sum();
    assert_eq!(shard_total, push_bytes + fetch_bytes);
    assert_eq!(summary.bandwidth.shard_bytes.len(), 4);
}

#[test]
fn single_shard_no_link_is_the_legacy_protocol() {
    // shards.count = 1 with no link rate must behave exactly like the
    // pre-shard protocol: every opportunity is all-or-nothing, vnow stays
    // the degenerate 1.0/iteration clock, and bytes reconcile with the
    // copy counters.
    let cfg = sharded_cfg(
        1,
        BandwidthMode::Probabilistic { c_push: 0.3, c_fetch: 0.6, eps: 1e-8 },
    );
    let s = build_sim(&cfg).unwrap().run().unwrap();
    assert_eq!(s.virtual_secs, s.iters as f64, "no wire charges");
    let b = &s.bandwidth;
    assert_eq!(b.push_bytes, b.push_copies * b.bytes_per_copy);
    assert_eq!(b.fetch_bytes, b.fetch_copies * b.bytes_per_copy);
    assert_eq!(b.shard_bytes, vec![b.total_bytes()]);
}

#[test]
fn barrier_broadcast_is_metered() {
    // A sync release hands θ_T to all λ clients: that broadcast is λ
    // fetch transmissions on the wire, not free — otherwise the vsecs
    // axis would be biased toward barrier policies.
    let mut cfg = fast_test_config(Policy::Sync);
    cfg.clients = 4;
    cfg.iters = 240; // 60 full barrier cycles
    let s = build_sim(&cfg).unwrap().run().unwrap();
    let copy = s.bandwidth.bytes_per_copy;
    assert_eq!(s.bandwidth.push_bytes, s.iters * copy, "forced pushes");
    assert_eq!(s.bandwidth.fetch_copies, s.bandwidth.fetch_potential);
    assert_eq!(
        s.bandwidth.fetch_bytes,
        s.server_updates * cfg.clients as u64 * copy,
        "each release broadcasts λ copies"
    );
}

#[test]
fn sharded_fasgd_still_learns() {
    // Gating chunks independently must not break convergence at mild c.
    let mut cfg = sharded_cfg(
        7,
        BandwidthMode::Probabilistic { c_push: 0.1, c_fetch: 0.3, eps: 1e-8 },
    );
    cfg.iters = 600;
    let s = build_sim(&cfg).unwrap().run().unwrap();
    let first = s.history.evals.first().unwrap().val_loss;
    let last = s.final_val_loss();
    assert!(last < first, "no learning under sharded gating: {first} -> {last}");
}

// ---------------------------------------------------------------------------
// Validation fences.

#[test]
fn probabilistic_rejected_without_v_stats() {
    let mut cfg = fast_test_config(Policy::Asgd);
    cfg.bandwidth = BandwidthMode::Probabilistic {
        c_push: 0.3,
        c_fetch: 0.0,
        eps: 1e-8,
    };
    let err = build_sim(&cfg).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("statistics"), "{msg}");
    assert!(msg.contains("fasgd"), "should name supporting policies: {msg}");
}

#[test]
fn sharding_config_fences() {
    let mut cfg = fast_test_config(Policy::Fasgd);
    cfg.shards.count = 4;
    cfg.push_drop = PushDropMode::Accumulate;
    assert!(build_sim(&cfg).is_err(), "accumulate is whole-model only");
    cfg.push_drop = PushDropMode::Skip;
    build_sim(&cfg).unwrap();
    cfg.shards.count = 0;
    assert!(build_sim(&cfg).is_err());
}
