//! Checkpoint/resume contract (server/checkpoint.rs): a run killed at
//! iteration k and resumed from its last checkpoint produces a tail
//! bitwise-identical to the uninterrupted run — evals, fault history,
//! and the summary minus `wall_secs` — in serial, pipelined-parallel,
//! and windowed modes, with faults enabled. Checkpoints are written at
//! drained boundaries, so serial and pipelined runs write identical
//! bytes and either mode can resume the other's file.

use std::path::PathBuf;

use fasgd::config::{ExperimentConfig, FaultConfig, Policy};
use fasgd::experiments::common::fast_test_config;
use fasgd::metrics::RunSummary;
use fasgd::sim::Simulation;

fn resume_cfg(seed: u64) -> ExperimentConfig {
    let mut cfg = fast_test_config(Policy::Fasgd);
    cfg.seed = seed;
    cfg.clients = 5;
    cfg.iters = 300;
    cfg.eval_every = 60;
    // Faults on: the checkpoint must carry the fault plane's RNG
    // position and down-map, not just θ.
    cfg.fault = FaultConfig {
        crash_prob: 0.05,
        downtime: 4.0,
        push_loss: 0.1,
        fetch_loss: 0.05,
        push_dup: 0.08,
        fetch_dup: 0.05,
    };
    cfg
}

fn ckpt_dir(test: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("fasgd_resume_tests")
        .join(format!("{test}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Everything in a summary that must survive interruption bitwise.
fn fingerprint(s: &RunSummary) -> String {
    let mut out = String::new();
    for p in &s.history.evals {
        out.push_str(&format!(
            "eval {} {} {:?} {:?} {:?}\n",
            p.iter,
            p.server_ts,
            p.vtime.to_bits(),
            p.val_loss.to_bits(),
            p.val_acc.to_bits()
        ));
    }
    for (i, e) in &s.history.train_curve {
        out.push_str(&format!("train {} {:?}\n", i, e.to_bits()));
    }
    out.push_str(&format!(
        "vsecs {:?} updates {} staleness {} {} {} faults {:?} bw {} {}\n",
        s.virtual_secs.to_bits(),
        s.server_updates,
        s.staleness.total(),
        s.staleness.max(),
        s.staleness.mean().to_bits(),
        s.faults,
        s.bandwidth.push_bytes,
        s.bandwidth.fetch_bytes,
    ));
    out
}

fn build(cfg: &ExperimentConfig, workers: usize) -> Simulation {
    Simulation::builder(cfg.clone())
        .workers(workers)
        .build()
        .unwrap()
}

/// Run `cfg` uninterrupted (writing checkpoints along the way), then
/// resume its last checkpoint file with `resume_workers` workers and
/// assert the finished summary is bitwise-identical.
fn assert_resume_matches(
    cfg: &ExperimentConfig,
    run_workers: usize,
    resume_workers: usize,
    expect_ckpt_iter: u64,
) {
    let uninterrupted = build(cfg, run_workers).run().unwrap();

    let bytes = std::fs::read(&cfg.checkpoint.path).unwrap();
    let mut resumed = build(cfg, resume_workers);
    let iter = resumed.load_checkpoint(&bytes).unwrap();
    assert_eq!(
        iter, expect_ckpt_iter,
        "last checkpoint landed at an unexpected boundary"
    );
    let summary = resumed.run().unwrap();
    assert_eq!(
        fingerprint(&uninterrupted),
        fingerprint(&summary),
        "resumed tail diverged (run workers {run_workers}, resume \
         workers {resume_workers})"
    );
}

#[test]
fn serial_resume_matches_uninterrupted() {
    let mut cfg = resume_cfg(11);
    cfg.checkpoint.path = ckpt_dir("serial")
        .join("run.ckpt")
        .to_string_lossy()
        .into_owned();
    // 128 ∤ 300: the last write (iter 256) precedes the end of the run,
    // so the resume actually replays a tail.
    cfg.checkpoint.every_iters = 128;
    assert_resume_matches(&cfg, 1, 1, 256);
}

#[test]
fn parallel_resume_crosses_execution_modes() {
    // The record is mode-agnostic and the fingerprint ignores execution
    // geometry: a serial run's checkpoint resumes on a worker pool and a
    // parallel run's checkpoint resumes serially, bitwise either way.
    let mut cfg = resume_cfg(23);
    cfg.checkpoint.path = ckpt_dir("cross")
        .join("run.ckpt")
        .to_string_lossy()
        .into_owned();
    cfg.checkpoint.every_iters = 128;
    assert_resume_matches(&cfg, 1, 4, 256);
    assert_resume_matches(&cfg, 4, 1, 256);
    assert_resume_matches(&cfg, 4, 4, 256);
}

#[test]
fn serial_and_pipelined_checkpoints_are_byte_identical() {
    // At a drained boundary both drivers hold exactly the serial-order
    // state, pending-pick record included (always `None` for these two
    // modes) — the files they write must match byte for byte.
    let cfg = resume_cfg(37);
    let mut serial = build(&cfg, 1);
    serial.run_until(176).unwrap();
    let mut parallel = build(&cfg, 4);
    parallel.run_until(176).unwrap();
    let a = serial.save_checkpoint().unwrap();
    let b = parallel.save_checkpoint().unwrap();
    assert_eq!(a, b, "drained-boundary checkpoints diverged");
}

#[test]
fn windowed_checkpoint_with_buffered_pick_resumes_serially() {
    // The windowed planner stashes a repeat-cut pick with its RNG draws
    // already consumed, so a drained boundary can carry a buffered pick.
    // Scan boundaries until one does (the bytes differ from the serial
    // checkpoint at the same iteration), then prove a serial resume of
    // that checkpoint still reproduces the uninterrupted tail.
    let mut cfg = resume_cfg(53);
    cfg.pipeline = false;
    let serial_cfg = {
        let mut c = cfg.clone();
        c.pipeline = true; // irrelevant at workers=1; keep defaults
        c
    };
    let mut exercised = false;
    for k in [90u64, 97, 104, 111, 118, 125] {
        let mut windowed = build(&cfg, 4);
        windowed.run_until(k).unwrap();
        let bytes = windowed.save_checkpoint().unwrap();

        let mut serial = build(&serial_cfg, 1);
        serial.run_until(k).unwrap();
        let serial_bytes = serial.save_checkpoint().unwrap();
        if bytes != serial_bytes {
            exercised = true;
        }

        // Whatever the schedule state, a fresh serial simulation must
        // continue the windowed checkpoint to the exact serial end state.
        let mut resumed = build(&serial_cfg, 1);
        assert_eq!(resumed.load_checkpoint(&bytes).unwrap(), k);
        resumed.run_until(cfg.iters).unwrap();
        serial.run_until(cfg.iters).unwrap();
        assert_eq!(
            serial.server().params(),
            resumed.server().params(),
            "serial resume of a windowed checkpoint at {k} diverged"
        );
        assert_eq!(
            serial.server().timestamp(),
            resumed.server().timestamp()
        );
    }
    assert!(
        exercised,
        "no scanned boundary carried a buffered pick; widen the scan \
         so the pending-pick path is actually tested"
    );
}

#[test]
fn virtual_seconds_cadence_writes_and_resumes() {
    let mut cfg = resume_cfg(71);
    cfg.checkpoint.path = ckpt_dir("vsecs")
        .join("run.ckpt")
        .to_string_lossy()
        .into_owned();
    cfg.checkpoint.every_vsecs = 130.0;
    let uninterrupted = build(&cfg, 1).run().unwrap();

    let bytes = std::fs::read(&cfg.checkpoint.path).unwrap();
    let mut resumed = build(&cfg, 1);
    let iter = resumed.load_checkpoint(&bytes).unwrap();
    assert!(
        iter > 0 && iter < cfg.iters,
        "vsecs cadence should checkpoint mid-run, got iteration {iter}"
    );
    let summary = resumed.run().unwrap();
    assert_eq!(fingerprint(&uninterrupted), fingerprint(&summary));
}

#[test]
fn mismatched_config_and_corrupt_files_fail_loudly() {
    let cfg = resume_cfg(83);
    let mut sim = build(&cfg, 1);
    sim.run_until(64).unwrap();
    let bytes = sim.save_checkpoint().unwrap();

    // Same bytes, drifted config: the fingerprint names the cause.
    let mut other = cfg.clone();
    other.alpha *= 2.0;
    let err = build(&other, 1).load_checkpoint(&bytes).unwrap_err();
    assert!(format!("{err:#}").contains("fingerprint"), "{err:#}");

    // Execution geometry is not config drift.
    let mut wide = cfg.clone();
    wide.inflight = 16;
    build(&wide, 4).load_checkpoint(&bytes).unwrap();

    // Truncation fails with an error, not a panic.
    let err = build(&cfg, 1)
        .load_checkpoint(&bytes[..bytes.len() / 2])
        .unwrap_err();
    assert!(!format!("{err:#}").is_empty());

    // Trailing garbage is rejected — a half-consumed record means the
    // reader and writer disagree about the layout.
    let mut padded = bytes.clone();
    padded.extend_from_slice(&[0u8; 8]);
    assert!(build(&cfg, 1).load_checkpoint(&padded).is_err());
}
