//! Policy-level integration through the full simulator: staleness
//! semantics, FASGD vs baselines, failure injection.

use anyhow::bail;
use fasgd::config::Policy;
use fasgd::experiments::common::{build_sim, fast_test_config, run_experiment};
use fasgd::grad::{Batch, GradientEngine, RustMlpEngine};
use fasgd::sim::dispatcher::{DataSource, SimParts, Simulator};

#[test]
fn single_client_has_minimal_staleness() {
    // λ=1 with always-on fetch: every gradient is computed at the latest
    // parameters, so τ ≤ ... = 0 after each fetch.
    let mut cfg = fast_test_config(Policy::Sasgd);
    cfg.clients = 1;
    cfg.iters = 200;
    let s = run_experiment(&cfg).unwrap();
    assert_eq!(s.staleness.mean(), 0.0);
    assert_eq!(s.staleness.max(), 0);
}

#[test]
fn staleness_grows_with_lambda() {
    let mean_tau = |lambda: usize| {
        let mut cfg = fast_test_config(Policy::Asgd);
        cfg.clients = lambda;
        cfg.iters = 2_000;
        run_experiment(&cfg).unwrap().staleness.mean()
    };
    let t4 = mean_tau(4);
    let t16 = mean_tau(16);
    let t64 = mean_tau(64);
    assert!(t4 < t16 && t16 < t64, "{t4} {t16} {t64}");
    // Uniform selection ⇒ mean staleness ≈ λ-1.
    assert!((t64 - 63.0).abs() < 8.0, "{t64}");
}

#[test]
fn all_async_policies_learn_at_their_rates() {
    for (policy, ok_threshold) in [
        (Policy::Asgd, 1.0),
        (Policy::Sasgd, 1.0),
        (Policy::Exponential, 1.5),
        (Policy::Fasgd, 1.0),
    ] {
        let mut cfg = fast_test_config(policy.clone());
        cfg.iters = 1_500;
        let s = run_experiment(&cfg).unwrap();
        assert!(
            s.final_val_loss() < ok_threshold,
            "{policy:?}: {}",
            s.final_val_loss()
        );
    }
}

#[test]
fn fasgd_beats_sasgd_under_heavy_staleness_pure_rust() {
    // A smaller-scale version of the paper's core claim on the pure-rust
    // path (the XLA path is exercised by runtime_roundtrip + examples).
    let run = |policy: Policy, alpha: f32| {
        let mut cfg = fast_test_config(policy);
        cfg.clients = 32;
        cfg.batch = 2;
        cfg.iters = 4_000;
        cfg.alpha = alpha;
        cfg.eval_every = 1_000;
        run_experiment(&cfg).unwrap().history.tail_mean(3)
    };
    let fasgd = run(Policy::Fasgd, 0.005);
    let sasgd = run(Policy::Sasgd, 0.04);
    assert!(
        fasgd < sasgd + 0.05,
        "FASGD {fasgd:.4} should not lose clearly to SASGD {sasgd:.4}"
    );
}

#[test]
fn exponential_penalty_lags_sasgd_at_high_staleness() {
    // The paper's criticism of Chan & Lane: the exponential penalty
    // "will reduce the learning rate too far when staleness values are
    // large". At λ=64 (mean τ≈63) it doesn't fully freeze — the low-τ tail
    // of the staleness distribution still learns — but it must trail
    // SASGD's gentler 1/τ under identical conditions.
    let run = |policy: Policy, rho: f32| {
        let mut cfg = fast_test_config(policy);
        cfg.clients = 64;
        cfg.rho = rho;
        cfg.iters = 2_000;
        cfg.eval_every = 500;
        run_experiment(&cfg).unwrap().final_val_loss()
    };
    let exp = run(Policy::Exponential, 0.5);
    let sasgd = run(Policy::Sasgd, 0.0);
    assert!(
        exp > sasgd * 1.5,
        "exponential ({exp:.4}) should clearly trail SASGD ({sasgd:.4})"
    );
}

// ---------------------------------------------------------------------------
// failure injection
// ---------------------------------------------------------------------------

/// Engine that fails deterministically after `ok_calls` gradients.
struct FailingEngine {
    inner: RustMlpEngine,
    calls: usize,
    ok_calls: usize,
}

impl GradientEngine for FailingEngine {
    fn param_count(&self) -> usize {
        self.inner.param_count()
    }

    fn grad(
        &mut self,
        theta: &[f32],
        batch: &Batch<'_>,
        grad_out: &mut [f32],
    ) -> anyhow::Result<f32> {
        self.calls += 1;
        if self.calls > self.ok_calls {
            bail!("injected gradient failure at call {}", self.calls);
        }
        self.inner.grad(theta, batch, grad_out)
    }
}

#[test]
fn grad_failure_surfaces_and_state_stays_consistent() {
    let mut cfg = fast_test_config(Policy::Fasgd);
    cfg.iters = 100;
    let sizes = vec![784, cfg.mlp_hidden, 10];
    let init = fasgd::grad::rust_mlp::init_params(cfg.seed, &sizes);
    let split = fasgd::data::synthetic::generate(
        cfg.seed, cfg.dataset.train, cfg.dataset.val, cfg.dataset.noise);
    let server = fasgd::server::build_server(
        &cfg, init, fasgd::server::UpdateEngine::Rust).unwrap();
    let parts = SimParts {
        server,
        grad: Box::new(FailingEngine {
            inner: RustMlpEngine::new(sizes.clone(), cfg.batch),
            calls: 0,
            ok_calls: 10,
        }),
        eval: Box::new(RustMlpEngine::new(sizes, 64)),
        data: DataSource::Classif(split),
    };
    let mut sim = Simulator::new(cfg, parts).unwrap();
    let mut errors = 0;
    for _ in 0..12 {
        if sim.step().is_err() {
            errors += 1;
        }
    }
    assert!(errors > 0, "failure should surface");
    // Server timestamp must match the number of successful pushes (10).
    assert_eq!(sim.server().timestamp(), 10);
}

#[test]
fn mismatched_engine_and_server_rejected() {
    let cfg = fast_test_config(Policy::Fasgd);
    let sizes = vec![784, cfg.mlp_hidden, 10];
    let split = fasgd::data::synthetic::generate(1, 64, 32, 0.3);
    let parts = SimParts {
        server: fasgd::server::build_server(
            &cfg,
            vec![0.0; 7], // wrong P
            fasgd::server::UpdateEngine::Rust,
        )
        .unwrap(),
        grad: Box::new(RustMlpEngine::new(sizes.clone(), cfg.batch)),
        eval: Box::new(RustMlpEngine::new(sizes, 32)),
        data: DataSource::Classif(split),
    };
    assert!(Simulator::new(cfg, parts).is_err());
}
