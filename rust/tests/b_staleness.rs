//! Validation of the paper's central hypothesis (§2.2): true B-Staleness
//! Γ (eq. 3) is tracked by the statistics FASGD maintains, and grows with
//! both the cluster size λ and the step-staleness τ.

use fasgd::config::Policy;
use fasgd::experiments::common::{fast_test_config, run_experiment};
use fasgd::metrics::RunSummary;

fn probed(lambda: usize, alpha: f32, iters: u64) -> RunSummary {
    let mut cfg = fast_test_config(Policy::Fasgd);
    cfg.clients = lambda;
    cfg.alpha = alpha;
    cfg.iters = iters;
    cfg.probe_every = 7;
    run_experiment(&cfg).unwrap()
}

#[test]
fn probe_records_and_is_nonintrusive() {
    let with = probed(8, 0.005, 600);
    assert!(!with.probes.is_empty());
    assert!(with.probes.records.len() >= 80);
    // Instrumentation must not change training: same run without probes.
    let mut cfg = fast_test_config(Policy::Fasgd);
    cfg.clients = 8;
    cfg.iters = 600;
    let without = run_experiment(&cfg).unwrap();
    let a: Vec<f64> = with.history.evals.iter().map(|p| p.val_loss).collect();
    let b: Vec<f64> =
        without.history.evals.iter().map(|p| p.val_loss).collect();
    assert_eq!(a, b, "probe perturbed the training run");
}

#[test]
fn gamma_zero_when_fresh() {
    // λ=1 with always-fetch: client params == server params at grad time,
    // so the recomputed gradient is identical and Γ = 0 exactly.
    let s = probed(1, 0.005, 200);
    assert!(s.probes.records.iter().all(|r| r.b_staleness == 0.0));
    assert!(s.probes.records.iter().all(|r| r.tau == 0));
}

#[test]
fn gamma_grows_with_lambda() {
    // More clients ⇒ staler gradients ⇒ larger true drift. Compare early
    // training (same iteration range) at two cluster sizes.
    let small = probed(2, 0.005, 400);
    let large = probed(32, 0.005, 400);
    assert!(
        large.probes.mean_gamma() > small.probes.mean_gamma(),
        "Γ: λ=32 {} vs λ=2 {}",
        large.probes.mean_gamma(),
        small.probes.mean_gamma()
    );
}

#[test]
fn v_tracks_gamma_better_than_nothing() {
    // The paper's claim is that v carries signal about Γ. Correlation over
    // a training run (where both decay together as the model converges)
    // should be clearly positive.
    let s = probed(16, 0.005, 1_500);
    let v_corr = s.probes.v_gamma_correlation().expect("enough probes");
    assert!(v_corr > 0.3, "corr(v̄, Γ) = {v_corr}");
}

#[test]
fn tau_alone_is_a_weak_predictor_within_a_run() {
    // Step-staleness τ is bounded by the fixed λ and quickly becomes
    // uninformative *within* a run (it fluctuates around λ-1 while Γ decays
    // over training) — the slack the paper exploits. We only assert the
    // probe exposes both numbers; the comparative analysis lives in
    // EXPERIMENTS.md.
    let s = probed(16, 0.005, 1_000);
    let taus: Vec<u64> = s.probes.records.iter().map(|r| r.tau).collect();
    assert!(taus.iter().any(|&t| t > 0));
    let t_corr = s.probes.tau_gamma_correlation();
    assert!(t_corr.is_some());
}
