//! Validation of the paper's central hypothesis (§2.2): true B-Staleness
//! Γ (eq. 3) is tracked by the statistics FASGD maintains, and grows with
//! both the cluster size λ and the step-staleness τ — plus, with the
//! virtual clock, that step-staleness is an *emergent* consequence of
//! client lateness rather than an artifact of pick probabilities.

use fasgd::config::{DelayModel, Policy};
use fasgd::experiments::common::{fast_test_config, run_experiment};
use fasgd::metrics::RunSummary;
use fasgd::sim::{Event, Simulation};

fn probed(lambda: usize, alpha: f32, iters: u64) -> RunSummary {
    let mut cfg = fast_test_config(Policy::Fasgd);
    cfg.clients = lambda;
    cfg.alpha = alpha;
    cfg.iters = iters;
    cfg.probe_every = 7;
    run_experiment(&cfg).unwrap()
}

#[test]
fn probe_records_and_is_nonintrusive() {
    let with = probed(8, 0.005, 600);
    assert!(!with.probes.is_empty());
    assert!(with.probes.records.len() >= 80);
    // Instrumentation must not change training: same run without probes.
    let mut cfg = fast_test_config(Policy::Fasgd);
    cfg.clients = 8;
    cfg.iters = 600;
    let without = run_experiment(&cfg).unwrap();
    let a: Vec<f64> = with.history.evals.iter().map(|p| p.val_loss).collect();
    let b: Vec<f64> =
        without.history.evals.iter().map(|p| p.val_loss).collect();
    assert_eq!(a, b, "probe perturbed the training run");
}

#[test]
fn gamma_zero_when_fresh() {
    // λ=1 with always-fetch: client params == server params at grad time,
    // so the recomputed gradient is identical and Γ = 0 exactly.
    let s = probed(1, 0.005, 200);
    assert!(s.probes.records.iter().all(|r| r.b_staleness == 0.0));
    assert!(s.probes.records.iter().all(|r| r.tau == 0));
}

#[test]
fn gamma_grows_with_lambda() {
    // More clients ⇒ staler gradients ⇒ larger true drift. Compare early
    // training (same iteration range) at two cluster sizes.
    let small = probed(2, 0.005, 400);
    let large = probed(32, 0.005, 400);
    assert!(
        large.probes.mean_gamma() > small.probes.mean_gamma(),
        "Γ: λ=32 {} vs λ=2 {}",
        large.probes.mean_gamma(),
        small.probes.mean_gamma()
    );
}

#[test]
fn v_tracks_gamma_better_than_nothing() {
    // The paper's claim is that v carries signal about Γ. Correlation over
    // a training run (where both decay together as the model converges)
    // should be clearly positive.
    let s = probed(16, 0.005, 1_500);
    let v_corr = s.probes.v_gamma_correlation().expect("enough probes");
    assert!(v_corr > 0.3, "corr(v̄, Γ) = {v_corr}");
}

#[test]
fn tau_alone_is_a_weak_predictor_within_a_run() {
    // Step-staleness τ is bounded by the fixed λ and quickly becomes
    // uninformative *within* a run (it fluctuates around λ-1 while Γ decays
    // over training) — the slack the paper exploits. We only assert the
    // probe exposes both numbers; the comparative analysis lives in
    // EXPERIMENTS.md.
    let s = probed(16, 0.005, 1_000);
    let taus: Vec<u64> = s.probes.records.iter().map(|r| r.tau).collect();
    assert!(taus.iter().any(|&t| t > 0));
    let t_corr = s.probes.tau_gamma_correlation();
    assert!(t_corr.is_some());
}

#[test]
fn staleness_is_emergent_and_sane_under_bimodal_stragglers() {
    // With the virtual clock on, τ is no longer a by-product of pick
    // order: a straggler's gradient genuinely arrives after the server
    // moved. The slow cohort (bimodal delay: clients [0, ceil(0.25·8))
    // = {0, 1}, 8× slower) must therefore show strictly larger empirical
    // mean τ at apply time than the fast cohort.
    let mut cfg = fast_test_config(Policy::Asgd);
    cfg.clients = 8;
    cfg.iters = 2_000;
    cfg.eval_every = 1_000;
    cfg.delay.compute = DelayModel::Bimodal {
        straggler_frac: 0.25,
        slow_mult: 8.0,
    };
    let mut sim = Simulation::builder(cfg.clone())
        .trace(16_384)
        .build()
        .unwrap();
    sim.run_until(cfg.iters).unwrap();
    let trace = sim.trace();
    assert_eq!(
        trace.recorded() as usize,
        trace.events().len(),
        "trace ring overflowed; cohort means would be biased to the tail"
    );
    let (mut slow, mut fast) = ((0u64, 0u64), (0u64, 0u64)); // (Στ, n)
    for e in trace.events() {
        if let Event::Applied { client, tau, reapplied: false, .. } = e {
            let cohort = if client < 2 { &mut slow } else { &mut fast };
            cohort.0 += tau;
            cohort.1 += 1;
        }
    }
    assert!(slow.1 > 0, "stragglers never applied");
    assert!(fast.1 > 0);
    // Completion order must also make stragglers *run less often*.
    assert!(
        fast.1 > 2 * slow.1,
        "fast cohort should dominate applies: slow={} fast={}",
        slow.1,
        fast.1
    );
    let mean_slow = slow.0 as f64 / slow.1 as f64;
    let mean_fast = fast.0 as f64 / fast.1 as f64;
    assert!(
        mean_slow > mean_fast,
        "emergent staleness inverted: slow cohort mean τ {mean_slow:.2} \
         vs fast {mean_fast:.2}"
    );
}

#[test]
fn staleness_aware_policies_still_learn_under_stragglers() {
    // fasgd and gap_aware must keep reaching the micro workload's learned
    // regime when staleness comes from real (virtual-time) lateness
    // instead of selection probabilities.
    for policy in [Policy::Fasgd, Policy::GapAware] {
        let mut cfg = fast_test_config(policy.clone());
        cfg.clients = 8;
        cfg.iters = 1_000;
        cfg.delay.compute = DelayModel::Bimodal {
            straggler_frac: 0.25,
            slow_mult: 8.0,
        };
        cfg.delay.network = DelayModel::LogNormal { mu: -2.0, sigma: 0.3 };
        let s = run_experiment(&cfg).unwrap();
        let first = s.history.evals.first().unwrap().val_loss;
        let last = s.final_val_loss();
        assert!(
            last < first,
            "{policy:?} stopped learning under delays: {first} -> {last}"
        );
        // ~ln(10) ≈ 2.3 is chance level on the 10-class micro workload;
        // the seed runs end well below 2.0 and delays must not undo that.
        assert!(last < 2.0, "{policy:?} final loss {last}");
        assert!(
            s.staleness.mean() > 0.0,
            "async under delays must still observe staleness"
        );
        assert!(s.virtual_secs > 0.0);
    }
}
