//! Regenerates Figure 3 (B-FASGD: convergence + bandwidth for sweeps of
//! `c_fetch` (top row) and `c_push` (bottom row)).
//!
//! Claims checked: fetch gating is nearly free out to large reductions;
//! push gating hurts; the copies-vs-potential ratio tightens as training
//! progresses (v decays ⇒ eq. 9 transmits less — "negative second
//! derivative").

use fasgd::bench_util::bench_iters;
use fasgd::config::ExperimentConfig;
use fasgd::experiments::fig3;

fn main() -> anyhow::Result<()> {
    fasgd::util::logging::init();
    let mut base = ExperimentConfig::default();
    base.iters = bench_iters(6_000);
    base.clients = 16;
    base.batch = 8;
    base.eval_every = (base.iters / 10).max(1);
    println!("fig3 bench: iters={} (paper: 100000)\n", base.iters);

    let results = fig3::run(&base, &fig3::C_VALUES)?;
    fig3::report(&results, std::path::Path::new("results/bench"))?;

    // Shape checks.
    let base_cost = results
        .iter()
        .find(|p| p.c == 0.0)
        .map(|p| p.run.history.tail_mean(3))
        .unwrap_or(f64::NAN);
    let worst_fetch = results
        .iter()
        .filter(|p| p.dir == fig3::SweepDir::Fetch && p.c > 0.0)
        .map(|p| p.run.history.tail_mean(3))
        .fold(f64::NEG_INFINITY, f64::max);
    let worst_push = results
        .iter()
        .filter(|p| p.dir == fig3::SweepDir::Push && p.c > 0.0)
        .map(|p| p.run.history.tail_mean(3))
        .fold(f64::NEG_INFINITY, f64::max);
    println!(
        "baseline {base_cost:.4} | worst gated-fetch {worst_fetch:.4} | worst gated-push {worst_push:.4}"
    );
    println!(
        "paper shape: gated-fetch ≈ baseline even at strong gating; \
         gated-push degrades first."
    );
    Ok(())
}
