//! Regenerates Figure 1 (FASGD vs SASGD, 4 (µ,λ) panels, µλ=128).
//!
//! `cargo bench --bench fig1` runs a reduced-iteration version (the shape
//! of the result — who wins in each panel — is the deliverable).
//! `FASGD_BENCH_ITERS=100000 cargo bench --bench fig1` reproduces the
//! paper's full budget; `repro fig1 --iters 100000` is equivalent.

use fasgd::bench_util::bench_iters;
use fasgd::config::ExperimentConfig;
use fasgd::experiments::fig1;

fn main() -> anyhow::Result<()> {
    fasgd::util::logging::init();
    let mut base = ExperimentConfig::default();
    base.iters = bench_iters(3_000);
    base.eval_every = (base.iters / 10).max(1);
    println!("fig1 bench: iters={} (paper: 100000)\n", base.iters);

    let results = fig1::run(&base)?;
    fig1::report(&results, std::path::Path::new("results/bench"))?;

    let wins = results.iter().filter(|r| r.fasgd_wins()).count();
    println!(
        "FASGD wins {wins}/{} panels (paper: 4/4 at 100k iterations)",
        results.len()
    );
    Ok(())
}
