//! Regenerates Figure 2 (λ-scaling: FASGD vs SASGD at µ=128).
//!
//! Default: λ ∈ {250, 500, 1000} with a reduced iteration budget; the
//! paper's λ=10000 point is included when `FASGD_BENCH_FULL=1` (it needs
//! ≥30k iterations and ~7 GB of client parameter copies — see DESIGN.md
//! §10). `repro fig2 --iters 100000` runs the paper's full configuration.

use fasgd::bench_util::bench_iters;
use fasgd::config::ExperimentConfig;
use fasgd::experiments::fig2;

fn main() -> anyhow::Result<()> {
    fasgd::util::logging::init();
    let mut base = ExperimentConfig::default();
    base.iters = bench_iters(4_000);
    base.eval_every = (base.iters / 8).max(1);

    let mut lambdas = vec![250usize, 500, 1000];
    if std::env::var("FASGD_BENCH_FULL").is_ok() {
        lambdas.push(10_000);
    }
    println!(
        "fig2 bench: iters>={} lambdas={lambdas:?} (paper: 100000 iters, +lambda=10000)\n",
        base.iters
    );

    let results = fig2::run(&base, &lambdas)?;
    fig2::report(&results, std::path::Path::new("results/bench"))?;

    let wins = results.iter().filter(|r| r.fasgd_wins()).count();
    println!("FASGD wins {wins}/{} lambda settings", results.len());
    let gaps: Vec<f64> = results.iter().map(|r| r.gap()).collect();
    let grows = gaps.windows(2).all(|w| w[1] >= w[0] - 0.02);
    println!(
        "gap vs lambda: {gaps:?} — {}",
        if grows {
            "non-decreasing (paper's scaling claim)"
        } else {
            "not monotone at this reduced budget"
        }
    );
    Ok(())
}
