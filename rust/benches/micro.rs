//! Micro-benchmarks for the L3 hot paths (EXPERIMENTS.md §Perf):
//! the fused FASGD server update, the SASGD axpy, the PJRT dispatch cost of
//! the grad/eval/update graphs, pure-rust grad, the dispatcher's per-step
//! overhead with gradient cost excluded, per-policy dispatcher throughput,
//! the serial vs. barrier-windowed vs. pipelined-speculative
//! dispatcher comparison (with the speculation miss-rate counter),
//! virtual-time throughput (simulated-seconds/sec on a straggler-fleet
//! delay-model workload), the sharded-gating workload
//! (bytes-on-wire/sec + the gated-vs-always byte reduction under
//! per-shard B-FASGD gating on a finite-rate link), the effective GB/s
//! of the chunks_exact(8)/mul_add kernels in tensor/ops.rs, and the
//! bounded-memory fleet row (lambda=1e5 snapshot-backed clients:
//! steps/sec + resident theta bytes).
//!
//! `cargo bench --bench micro -- --json BENCH_pr3.json` additionally
//! writes the throughput snapshot as JSON (the per-PR perf trajectory).

use std::time::Duration;

use fasgd::bench_util::Bench;
use fasgd::config::Policy;
use fasgd::grad::{Batch, GradientEngine, RustMlpEngine, XlaGradEngine};
use fasgd::sim::Simulation;
use fasgd::tensor::{fasgd_update_fused, FasgdHparams};
use fasgd::util::json::{obj, Json};

const P: usize = 159_010; // the paper MLP's flat parameter count

fn main() -> anyhow::Result<()> {
    fasgd::util::logging::init();
    let argv: Vec<String> = std::env::args().collect();
    let json_path = match argv.iter().position(|a| a == "--json") {
        Some(i) => match argv.get(i + 1) {
            Some(p) if !p.starts_with("--") => Some(p.clone()),
            _ => anyhow::bail!(
                "--json requires a path argument, e.g. \
                 `cargo bench --bench micro -- --json BENCH_pr2.json`"
            ),
        },
        None => None,
    };
    let bench = Bench::with_budget(Duration::from_millis(600));

    // --- server update engines over P=159010 --------------------------------
    let mut rng = fasgd::rng::stream(0, "bench", 0);
    let mut theta: Vec<f32> = (0..P).map(|_| rng.f32() - 0.5).collect();
    let mut n = vec![0.1f32; P];
    let mut b = vec![0.0f32; P];
    let mut v = vec![0.5f32; P];
    let g: Vec<f32> = (0..P).map(|_| rng.f32() - 0.5).collect();
    let hp = FasgdHparams::default();

    let stats = bench.run("fasgd_update_fused (rust, P=159010)", || {
        fasgd_update_fused(&mut theta, &mut n, &mut b, &mut v, &g, 1e-3, &hp);
    });
    let bytes = (P * 4 * 5) as f64; // 4 state streams rw + grad read ≈ 5 streams
    let fasgd_gbps = bytes * stats.per_sec() / 1e9;
    println!(
        "    -> {fasgd_gbps:.2} GB/s effective, {:.1} Melem/s",
        P as f64 * stats.per_sec() / 1e6
    );

    let sasgd_stats = bench.run("sasgd axpy apply (P=159010)", || {
        fasgd::tensor::sasgd_apply(&mut theta, &g, 1e-4);
    });
    let axpy_bytes = (P * 4 * 2) as f64; // theta rmw + grad read
    let axpy_gbps = axpy_bytes * sasgd_stats.per_sec() / 1e9;
    println!("    -> {axpy_gbps:.2} GB/s effective");

    // Blocked axpy: four gradient streams folded into theta in one pass
    // (the sync barrier's fan-in shape).
    let g1: Vec<f32> = (0..P).map(|_| rng.f32() - 0.5).collect();
    let g2: Vec<f32> = (0..P).map(|_| rng.f32() - 0.5).collect();
    let g3: Vec<f32> = (0..P).map(|_| rng.f32() - 0.5).collect();
    let coef = [-2.5e-4f32; 4];
    let block_stats = bench.run("axpy_block 4-stream (P=159010)", || {
        fasgd::tensor::axpy_block(&mut theta, &coef, &g, &g1, &g2, &g3);
    });
    let block_bytes = (P * 4 * 5) as f64; // theta rmw + four grad reads
    let axpy_block_gbps = block_bytes * block_stats.per_sec() / 1e9;
    println!("    -> {axpy_block_gbps:.2} GB/s effective");
    let kernels_block = obj(vec![
        ("p", P.into()),
        ("axpy_gb_per_sec", axpy_gbps.into()),
        ("axpy_block_gb_per_sec", axpy_block_gbps.into()),
        ("fasgd_update_fused_gb_per_sec", fasgd_gbps.into()),
    ]);

    // --- pure-rust grad engine ----------------------------------------------
    let split = fasgd::data::synthetic::generate(0, 256, 0, 0.35);
    let (x8, y8) = split.train.gather(&(0..8).collect::<Vec<_>>());
    let mut rust_engine = RustMlpEngine::paper(8);
    let mut grad_buf = vec![0.0f32; rust_engine.param_count()];
    let theta_mlp: Vec<f32> =
        fasgd::grad::rust_mlp::init_params(0, &[784, 200, 10]);
    bench.run("rust MLP grad (mu=8)", || {
        rust_engine
            .grad(&theta_mlp, &Batch::Classif { x: &x8, y: &y8 }, &mut grad_buf)
            .unwrap();
    });

    // --- PJRT graph dispatch -------------------------------------------------
    if fasgd::util::artifacts_dir().join("manifest.json").exists() {
        let engine = fasgd::experiments::common::shared_engine()?;
        for mu in [1usize, 8, 128] {
            let mut ge = XlaGradEngine::new(&engine, "mlp", mu)?;
            let idx: Vec<usize> = (0..mu).collect();
            let (x, y) = split.train.gather(&idx);
            let theta = engine.registry().load_init("mlp")?;
            let mut gb = vec![0.0f32; ge.param_count()];
            bench.run(&format!("xla MLP grad execute (mu={mu})"), || {
                ge.grad(&theta, &Batch::Classif { x: &x, y: &y }, &mut gb)
                    .unwrap();
            });
        }
        let upd = fasgd::grad::XlaUpdateEngine::new(&engine, P, &hp)?;
        bench.run("xla fasgd_update (Pallas artifact, P=159010)", || {
            upd.apply(&mut theta, &mut n, &mut b, &mut v, &g, 1e-3).unwrap();
        });
    } else {
        println!("(artifacts missing; skipping PJRT benches — run `make artifacts`)");
    }

    // --- dispatcher overhead (tiny model isolates coordination cost) --------
    let mut cfg = fasgd::experiments::common::fast_test_config(Policy::Fasgd);
    cfg.mlp_hidden = 1;
    cfg.batch = 1;
    cfg.iters = u64::MAX; // stepped manually
    cfg.eval_every = u64::MAX >> 1;
    let mut sim = fasgd::experiments::common::build_sim(&cfg)?;
    bench.run("dispatcher step (hidden=1: coordination + tiny grad)", || {
        sim.step().unwrap();
    });

    // --- barrier vs pipelined dispatcher throughput -------------------------
    // The async micro workload (paper-size MLP, λ=8, asgd): gradient-step
    // throughput of the serial dispatcher vs the worker pool in both
    // parallel flavors — the legacy per-window fan-out/fan-in loop
    // (`pipeline=false`) and the pipelined speculative dispatcher.
    // Acceptance bars: parallel ≥ 2x serial at 4 workers (PR 1) and
    // pipelined ≥ 1.3x barrier-mode at 4 workers (PR 3).
    let mk_cfg = || {
        let mut cfg =
            fasgd::experiments::common::fast_test_config(Policy::Asgd);
        cfg.clients = 8;
        cfg.batch = 8;
        cfg.mlp_hidden = 200; // the paper's 784-200-10
        cfg.alpha = 0.01;
        cfg.dataset.train = 4_096;
        cfg.dataset.val = 512;
        cfg.iters = u64::MAX >> 1; // advanced manually via step/run_until
        cfg.eval_every = u64::MAX >> 2;
        cfg
    };
    let iters = fasgd::bench_util::bench_iters(2_000);
    let warmup = iters / 4;

    let cfg = mk_cfg();
    let mut serial = fasgd::experiments::common::build_sim(&cfg)?;
    for _ in 0..warmup {
        serial.step()?;
    }
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        serial.step()?;
    }
    let serial_sps = iters as f64 / t0.elapsed().as_secs_f64();
    println!(
        "dispatcher serial   (mlp lambda=8 mu=8)          {serial_sps:>10.0} steps/s"
    );

    let mut speedup_at_4 = 0.0;
    let mut pipelined_vs_barrier_at_4 = 0.0;
    let mut barrier_rows: Vec<Json> = Vec::new();
    let mut pipelined_rows: Vec<Json> = Vec::new();
    for workers in [2usize, 4, 8] {
        // Legacy windowed (fan-out/fan-in barrier per window).
        let mut barrier_cfg = cfg.clone();
        barrier_cfg.pipeline = false;
        let mut par = fasgd::experiments::common::build_parallel_sim(
            &barrier_cfg,
            workers,
        )?;
        par.run_until(warmup)?;
        let t0 = std::time::Instant::now();
        par.run_until(warmup + iters)?;
        let barrier_sps = iters as f64 / t0.elapsed().as_secs_f64();
        println!(
            "dispatcher barrier  (mlp lambda=8 mu=8, {workers} workers) {barrier_sps:>10.0} steps/s  ({:.2}x serial)",
            barrier_sps / serial_sps
        );
        barrier_rows.push(obj(vec![
            ("workers", workers.into()),
            ("steps_per_sec", barrier_sps.into()),
            ("speedup_vs_serial", (barrier_sps / serial_sps).into()),
        ]));

        // Pipelined speculative (the default).
        let mut par =
            fasgd::experiments::common::build_parallel_sim(&cfg, workers)?;
        par.run_until(warmup)?;
        let spec0 = par.speculation();
        let t0 = std::time::Instant::now();
        par.run_until(warmup + iters)?;
        let sps = iters as f64 / t0.elapsed().as_secs_f64();
        let spec = par.speculation();
        let submitted = spec.submitted - spec0.submitted;
        let recomputed = spec.recomputed - spec0.recomputed;
        let miss_rate = if submitted == 0 {
            0.0
        } else {
            recomputed as f64 / submitted as f64
        };
        let speedup = sps / serial_sps;
        let vs_barrier = sps / barrier_sps;
        if workers == 4 {
            speedup_at_4 = speedup;
            pipelined_vs_barrier_at_4 = vs_barrier;
        }
        println!(
            "dispatcher pipelined(mlp lambda=8 mu=8, {workers} workers) {sps:>10.0} steps/s  ({speedup:.2}x serial, {vs_barrier:.2}x barrier, {:.1}% miss)",
            100.0 * miss_rate
        );
        pipelined_rows.push(obj(vec![
            ("workers", workers.into()),
            ("steps_per_sec", sps.into()),
            ("speedup_vs_serial", speedup.into()),
            ("speedup_vs_barrier", vs_barrier.into()),
            ("spec_submitted", (submitted as f64).into()),
            ("spec_recomputed", (recomputed as f64).into()),
            ("spec_miss_rate", miss_rate.into()),
        ]));
    }
    println!(
        "parallel speedup at 4 workers: {speedup_at_4:.2}x {}",
        if speedup_at_4 >= 2.0 { "(>= 2x target met)" } else { "(below 2x target)" }
    );
    println!(
        "pipelined vs barrier at 4 workers: {pipelined_vs_barrier_at_4:.2}x {}",
        if pipelined_vs_barrier_at_4 >= 1.3 {
            "(>= 1.3x target met)"
        } else {
            "(below 1.3x target)"
        }
    );

    // --- virtual-time throughput (simulated seconds per wall second) --------
    // The straggler-fleet workload: bimodal compute delays + lognormal
    // network jitter on the paper MLP, scheduled by the virtual clock
    // (completion-order selection). Reported as simulated-seconds/sec
    // alongside steps/sec — the clock's hot-path cost and the dispatcher's
    // simulation rate on time-driven scenarios both show up here.
    let mk_delay_cfg = || {
        let mut cfg = mk_cfg();
        cfg.delay.compute = fasgd::config::DelayModel::Bimodal {
            straggler_frac: 0.25,
            slow_mult: 8.0,
        };
        cfg.delay.network =
            fasgd::config::DelayModel::LogNormal { mu: -2.0, sigma: 0.3 };
        cfg
    };
    let cfg_d = mk_delay_cfg();
    let mut serial_d = fasgd::experiments::common::build_sim(&cfg_d)?;
    serial_d.run_until(warmup)?;
    let v0 = serial_d.virtual_secs();
    let t0 = std::time::Instant::now();
    serial_d.run_until(warmup + iters)?;
    let wall = t0.elapsed().as_secs_f64();
    let serial_d_sps = iters as f64 / wall;
    let serial_vsps = (serial_d.virtual_secs() - v0) / wall;
    println!(
        "dispatcher serial   (straggler fleet, vclock)    {serial_d_sps:>10.0} steps/s  {serial_vsps:>12.0} sim-secs/s"
    );
    let mut vclock_rows: Vec<Json> = vec![obj(vec![
        ("workers", 1usize.into()),
        ("steps_per_sec", serial_d_sps.into()),
        ("sim_secs_per_sec", serial_vsps.into()),
    ])];
    for workers in [2usize, 4, 8] {
        let mut par =
            fasgd::experiments::common::build_parallel_sim(&cfg_d, workers)?;
        par.run_until(warmup)?;
        let v0 = par.virtual_secs();
        let t0 = std::time::Instant::now();
        par.run_until(warmup + iters)?;
        let wall = t0.elapsed().as_secs_f64();
        let sps = iters as f64 / wall;
        let vsps = (par.virtual_secs() - v0) / wall;
        println!(
            "dispatcher pipelined(straggler fleet, vclock, {workers} workers) {sps:>10.0} steps/s  {vsps:>12.0} sim-secs/s  ({:.2}x serial)",
            sps / serial_d_sps
        );
        vclock_rows.push(obj(vec![
            ("workers", workers.into()),
            ("steps_per_sec", sps.into()),
            ("sim_secs_per_sec", vsps.into()),
            ("speedup_vs_serial", (sps / serial_d_sps).into()),
        ]));
    }

    // --- sharded B-FASGD gating: bytes-on-wire throughput -------------------
    // The paper MLP under per-shard probabilistic gating with a
    // finite-rate link: per-shard gate draws + byte accounting + wire-time
    // charging are all on the per-iteration path, so steps/sec here is the
    // sharding overhead and bytes-on-wire/sec is the simulated traffic
    // rate. The `always` twin gives the raw-bytes baseline the reduction
    // factor is measured against.
    let mk_sharded = |gated: bool| {
        let mut cfg =
            fasgd::experiments::common::fast_test_config(Policy::Fasgd);
        cfg.clients = 8;
        cfg.batch = 8;
        cfg.mlp_hidden = 200;
        cfg.dataset.train = 4_096;
        cfg.dataset.val = 512;
        cfg.iters = fasgd::bench_util::bench_iters(1_500);
        cfg.eval_every = u64::MAX >> 2;
        cfg.shards.count = 8;
        cfg.link.rate_bytes_per_vsec = 1e9;
        if gated {
            cfg.bandwidth = fasgd::config::BandwidthMode::Probabilistic {
                c_push: 0.3,
                c_fetch: 0.6,
                eps: 1e-8,
            };
        }
        cfg
    };
    let gated_run =
        fasgd::experiments::common::run_experiment(&mk_sharded(true))?;
    let always_run =
        fasgd::experiments::common::run_experiment(&mk_sharded(false))?;
    let gated_sps = gated_run.iters as f64 / gated_run.wall_secs;
    let gated_bps =
        gated_run.bandwidth.total_bytes() as f64 / gated_run.wall_secs;
    let raw_bytes = always_run.bandwidth.total_bytes();
    let gated_bytes = gated_run.bandwidth.total_bytes();
    let byte_reduction = if gated_bytes == 0 {
        f64::INFINITY
    } else {
        raw_bytes as f64 / gated_bytes as f64
    };
    println!(
        "sharded gating (8 shards, B-FASGD, vclock+link)  {gated_sps:>10.0} steps/s  {:>10.1} MB-on-wire/s  ({byte_reduction:.2}x byte cut vs always)",
        gated_bps / 1e6
    );
    let bandwidth_block = obj(vec![
        (
            "workload",
            "mlp lambda=8 mu=8 hidden=200, shards=8, probabilistic \
             c_push=0.3 c_fetch=0.6, link 1e9 B/vs"
                .into(),
        ),
        ("shards", 8usize.into()),
        ("steps_per_sec", gated_sps.into()),
        ("bytes_on_wire_per_sec", gated_bps.into()),
        ("gated_bytes", gated_bytes.into()),
        ("raw_bytes", raw_bytes.into()),
        (
            "byte_reduction_vs_always",
            if byte_reduction.is_finite() { byte_reduction } else { -1.0 }
                .into(),
        ),
        ("virtual_secs_gated", gated_run.virtual_secs.into()),
        ("virtual_secs_always", always_run.virtual_secs.into()),
    ]);

    // --- concurrent sharded commits: server apply throughput ----------------
    // The PR 9 striped commit plane vs the serial oracle at the paper MLP
    // size (P=159010, 8 shards, fasgd rule). Serial applies run inline on
    // the caller; sharded applies enqueue to the committer pool, and the
    // clock stops only after a quiesce so every enqueued commit is paid
    // for inside the measured window.
    use fasgd::server::{
        FasgdServer, ParamStore, RustBackend, Server, ShardedServer,
    };
    let cshards = 8usize;
    let capply = fasgd::bench_util::bench_iters(600);
    let cinit = vec![0.0f32; P];
    let mut serial_srv = FasgdServer::with_backend_sharded(
        cinit.clone(),
        5e-4,
        hp.clone(),
        RustBackend,
        ParamStore::new(P, cshards, 4),
    );
    let t0 = std::time::Instant::now();
    for _ in 0..capply {
        let ts = serial_srv.timestamp().saturating_sub(2);
        serial_srv.apply_update(&g, ts, 0)?;
    }
    let serial_aps = capply as f64 / t0.elapsed().as_secs_f64();
    println!(
        "server apply serial  (fasgd, P=159010, 8 shards)  {serial_aps:>10.0} applies/s"
    );
    let mut conc_rows: Vec<Json> = Vec::new();
    let mut shard_ts_buf = vec![0u64; cshards];
    for committers in [1usize, 2, 4] {
        let mut srv = ShardedServer::new_fasgd(
            cinit.clone(),
            ParamStore::new(P, cshards, 4),
            5e-4,
            hp.clone(),
            committers,
        );
        let spawned = srv.committer_count();
        let t0 = std::time::Instant::now();
        for _ in 0..capply {
            let ts = srv.timestamp().saturating_sub(2);
            shard_ts_buf.iter_mut().for_each(|t| *t = ts);
            srv.apply_update_sharded(&g, &shard_ts_buf, 0)?;
        }
        srv.quiesce()?;
        let aps = capply as f64 / t0.elapsed().as_secs_f64();
        println!(
            "server apply sharded (fasgd, P=159010, 8 shards, {committers} committers) {aps:>10.0} applies/s  ({:.2}x serial)",
            aps / serial_aps
        );
        conc_rows.push(obj(vec![
            ("committers", committers.into()),
            ("committers_spawned", spawned.into()),
            ("applies_per_sec", aps.into()),
            ("speedup_vs_serial", (aps / serial_aps).into()),
        ]));
    }
    let concurrency_block = obj(vec![
        (
            "workload",
            "fasgd apply, P=159010, 8 shards, uniform shard_ts \
             (enqueue + drain measured)"
                .into(),
        ),
        ("serial_applies_per_sec", serial_aps.into()),
        ("sharded", Json::Arr(conc_rows)),
    ]);

    // --- per-policy dispatcher throughput (serial, via the builder) ---------
    // Coordination + policy apply_update cost per step at the paper MLP
    // size; gap_aware pays an extra ||theta||_2 pass per update, fasgd the
    // fused four-stream update — this table is where such costs show up.
    let policy_iters = fasgd::bench_util::bench_iters(1_500);
    let mut policy_rows: Vec<Json> = Vec::new();
    for name in ["sync", "asgd", "sasgd", "exponential", "fasgd", "gap_aware"]
    {
        let mut cfg = mk_cfg();
        cfg.policy = Policy::custom(name);
        cfg.alpha = if name == "fasgd" { 0.005 } else { 0.01 };
        let mut sim = Simulation::builder(cfg).build()?;
        sim.run_until(policy_iters / 4)?; // warmup
        let t0 = std::time::Instant::now();
        sim.run_until(policy_iters / 4 + policy_iters)?;
        let sps = policy_iters as f64 / t0.elapsed().as_secs_f64();
        println!(
            "dispatcher serial per-policy ({name:<11})        {sps:>10.0} steps/s"
        );
        policy_rows.push(obj(vec![
            ("policy", name.into()),
            ("steps_per_sec", sps.into()),
        ]));
    }

    // --- bounded-memory fleet: lambda=1e5 snapshot-backed clients -----------
    // A hundred thousand bimodal-straggler clients share the epoch-indexed
    // snapshot ring: per-client state is shard epoch ids + a sampler
    // cursor, so resident theta memory is ring-depth * P * 4 bytes no
    // matter how large lambda grows. `resident_param_bytes` is the
    // run-end ring residency that the CI fleet smoke asserts a hard cap
    // on; steps/sec shows the dispatcher's cost of scheduling a fleet
    // four orders of magnitude wider than the worker pool.
    let mut fleet_cfg =
        fasgd::experiments::common::fast_test_config(Policy::Fasgd);
    fleet_cfg.clients = 100_000;
    fleet_cfg.iters = fasgd::bench_util::bench_iters(1_000);
    fleet_cfg.eval_every = u64::MAX >> 2;
    fleet_cfg.shards.count = 4;
    fleet_cfg.delay.compute = fasgd::config::DelayModel::Bimodal {
        straggler_frac: 0.1,
        slow_mult: 8.0,
    };
    let fleet_run = fasgd::experiments::common::run_experiment(&fleet_cfg)?;
    let fleet_sps = fleet_run.iters as f64 / fleet_run.wall_secs;
    println!(
        "fleet lambda=1e5 (bimodal, 4 shards, snapshots)  {fleet_sps:>10.0} steps/s  {:>8.3} MB resident theta",
        fleet_run.resident_param_bytes as f64 / 1e6
    );
    let fleet_block = obj(vec![
        (
            "workload",
            "lambda=1e5 snapshot-backed clients, bimodal stragglers \
             (10% at 8x), fasgd, 4 shards"
                .into(),
        ),
        ("lambda", 100_000usize.into()),
        ("shards", 4usize.into()),
        ("steps_per_sec", fleet_sps.into()),
        ("resident_param_bytes", fleet_run.resident_param_bytes.into()),
    ]);

    if let Some(path) = json_path {
        let snapshot = obj(vec![
            ("bench", "micro".into()),
            ("workload", "mlp lambda=8 mu=8 hidden=200 (pure-rust grad)".into()),
            ("serial_steps_per_sec", serial_sps.into()),
            ("parallel_barrier", Json::Arr(barrier_rows)),
            ("parallel_pipelined", Json::Arr(pipelined_rows)),
            (
                "virtual_time",
                obj(vec![
                    (
                        "workload",
                        "straggler fleet: bimodal compute (25% at 8x) + \
                         lognormal network, vclock completion order"
                            .into(),
                    ),
                    ("rows", Json::Arr(vclock_rows)),
                ]),
            ),
            ("per_policy_serial", Json::Arr(policy_rows)),
            ("bandwidth", bandwidth_block),
            ("concurrency", concurrency_block),
            ("kernels", kernels_block),
            ("fleet", fleet_block),
            ("speedup_at_4_workers", speedup_at_4.into()),
            (
                "pipelined_vs_barrier_at_4_workers",
                pipelined_vs_barrier_at_4.into(),
            ),
        ]);
        std::fs::write(&path, snapshot.to_string_pretty())?;
        println!("wrote throughput snapshot to {path}");
    }

    Ok(())
}
