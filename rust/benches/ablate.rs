//! Ablation benches for the design choices DESIGN.md §5 calls out:
//!
//! 1. eq. 6 interpretation — `v` as EMA of std (default) vs the literal
//!    printed EMA of 1/std;
//! 2. push-drop strategy — re-apply cached (paper) vs client-side
//!    accumulate (paper's suggested alternative) vs plain skip;
//! 3. staleness penalty family — SASGD's 1/τ vs Chan & Lane's exp(−ρτ)
//!    (the paper's "reduces the learning rate too far" criticism);
//! 4. update engine — fused rust loop vs AOT Pallas artifact (numerics; the
//!    speed side lives in benches/micro.rs).

use fasgd::bench_util::bench_iters;
use fasgd::config::{BandwidthMode, ExperimentConfig, Policy, PushDropMode,
                    UpdateEngineKind};
use fasgd::experiments::common::run_experiment;
use fasgd::metrics::writer::render_table;

fn main() -> anyhow::Result<()> {
    fasgd::util::logging::init();
    let iters = bench_iters(4_000);

    let mut base = ExperimentConfig::default();
    base.iters = iters;
    base.clients = 16;
    base.batch = 8;
    base.eval_every = (iters / 8).max(1);
    base.alpha = fasgd::experiments::fig1::FASGD_LR;

    // --- 1. eq. 6 variant -------------------------------------------------
    println!("== ablation: eq.6 v-track variant ==");
    let mut rows = Vec::new();
    for (label, inverse) in [("std (default)", false), ("inverse (literal)", true)] {
        let mut cfg = base.clone();
        cfg.name = format!("ablate-eq6-{}", if inverse { "inv" } else { "std" });
        cfg.fasgd.inverse_variant = inverse;
        let s = run_experiment(&cfg)?;
        rows.push(vec![
            label.to_string(),
            format!("{:.4}", s.history.tail_mean(3)),
            format!("{:.4}", s.best_val_loss()),
        ]);
    }
    println!("{}", render_table(&["variant", "final cost", "best cost"], &rows));

    // --- 2. push-drop strategy --------------------------------------------
    println!("== ablation: push-drop strategy (c_push=0.3) ==");
    let mut rows = Vec::new();
    for (label, mode) in [
        ("reapply cached (paper)", PushDropMode::ReapplyCached),
        ("accumulate (alt.)", PushDropMode::Accumulate),
        ("skip", PushDropMode::Skip),
    ] {
        let mut cfg = base.clone();
        cfg.name = format!("ablate-drop-{label}");
        cfg.bandwidth = BandwidthMode::Probabilistic {
            c_push: 0.3,
            c_fetch: 0.0,
            eps: 1e-8,
        };
        cfg.push_drop = mode;
        let s = run_experiment(&cfg)?;
        rows.push(vec![
            label.to_string(),
            format!("{:.4}", s.history.tail_mean(3)),
            format!("{:.3}", s.bandwidth.push_ratio()),
        ]);
    }
    println!(
        "{}",
        render_table(&["strategy", "final cost", "push copies/potential"], &rows)
    );

    // --- 3. staleness penalty family ---------------------------------------
    println!("== ablation: staleness penalty (lambda=64 for heavier tails) ==");
    let mut rows = Vec::new();
    for (policy, alpha, rho) in [
        (Policy::Sasgd, 0.04f32, 0.0f32),
        (Policy::Exponential, 0.04, 0.05),
        (Policy::Exponential, 0.04, 0.5),
        (Policy::Asgd, 0.005, 0.0),
        (Policy::Fasgd, 0.005, 0.0),
    ] {
        let mut cfg = base.clone();
        cfg.policy = policy.clone();
        cfg.alpha = alpha;
        cfg.rho = rho;
        cfg.clients = 64;
        cfg.batch = 2;
        cfg.name = format!("ablate-penalty-{}-rho{rho}", policy.name());
        let s = run_experiment(&cfg)?;
        rows.push(vec![
            format!("{}{}", policy.name(),
                    if policy == Policy::Exponential { format!("(rho={rho})") } else { String::new() }),
            format!("{:.4}", s.history.tail_mean(3)),
            format!("{:.1}", s.staleness.mean()),
        ]);
    }
    println!("{}", render_table(&["policy", "final cost", "mean tau"], &rows));
    println!(
        "paper claim: the exponential penalty over-suppresses at large tau; \
         SASGD's 1/tau is better, FASGD better still."
    );

    // --- 4. update engine numerics ------------------------------------------
    if fasgd::util::artifacts_dir().join("manifest.json").exists() {
        println!("== ablation: FASGD update engine (rust fused vs AOT Pallas) ==");
        let mut rows = Vec::new();
        for (label, engine) in [
            ("rust fused", UpdateEngineKind::Rust),
            ("xla pallas", UpdateEngineKind::Xla),
        ] {
            let mut cfg = base.clone();
            cfg.iters = iters.min(1_500); // per-update PJRT dispatch is slower
            cfg.update_engine = engine;
            cfg.name = format!("ablate-engine-{label}");
            let s = run_experiment(&cfg)?;
            rows.push(vec![
                label.to_string(),
                format!("{:.4}", s.history.tail_mean(3)),
                format!("{:.1}s", s.wall_secs),
            ]);
        }
        println!("{}", render_table(&["engine", "final cost", "wall"], &rows));
        println!("(identical math ⇒ costs should agree to f32 noise)");
    }
    Ok(())
}
