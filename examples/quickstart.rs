//! Quickstart: train the paper's MNIST MLP with FASGD, SASGD, and the
//! gap-aware policy on a small async cluster and compare validation-cost
//! curves — through the public [`Simulation`] builder API.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Everything here goes through the full three-layer stack: the gradient is
//! the AOT-lowered JAX graph (with the Pallas dense kernel inside) executed
//! via PJRT from the rust coordinator. Policies are resolved by name
//! through the open policy registry, and the eval table prints *live*
//! through a [`RunObserver`] instead of being dumped post-hoc.

use fasgd::config::{ExperimentConfig, Policy};
use fasgd::metrics::{EvalPoint, RunSummary};
use fasgd::sim::{RunObserver, Simulation};

/// Streams each validation point as the run records it.
struct LiveTable;

impl RunObserver for LiveTable {
    fn on_eval(&mut self, p: &EvalPoint) {
        println!("{:>6}    {:>8.4}   {:>6.3}", p.iter, p.val_loss, p.val_acc);
    }
}

fn main() -> anyhow::Result<()> {
    fasgd::util::logging::init();

    let mut base = ExperimentConfig::default();
    base.clients = 16; // λ
    base.batch = 8; // µ
    base.iters = 4_000;
    base.eval_every = 250;

    let mut rows: Vec<(Policy, RunSummary)> = Vec::new();
    for (policy, alpha) in [
        (Policy::Fasgd, 0.005f32),
        (Policy::Sasgd, 0.04),
        (Policy::GapAware, 0.04),
    ] {
        let mut cfg = base.clone();
        cfg.policy = policy.clone();
        cfg.alpha = alpha;
        cfg.name = format!("quickstart-{}", policy.name());

        println!("\n== {} (alpha={alpha}) ==", policy.name());
        println!("iter      val_cost   val_acc");
        let summary = Simulation::builder(cfg)
            .observer(LiveTable)
            .build()?
            .run()?;
        rows.push((policy, summary));
    }

    let (f, s) = (&rows[0].1, &rows[1].1);
    println!("\nfinal validation cost: FASGD {:.4} vs SASGD {:.4}  ({})",
        f.history.tail_mean(3),
        s.history.tail_mean(3),
        if f.history.tail_mean(3) < s.history.tail_mean(3) {
            "FASGD wins — the paper's Figure 1 claim"
        } else {
            "SASGD wins — unexpected at these settings"
        }
    );
    let ga = &rows[2].1;
    println!(
        "gap_aware (Barkai et al. 2019, via the open policy registry): {:.4}",
        ga.history.tail_mean(3)
    );
    println!("mean step-staleness: FASGD {:.2}, SASGD {:.2}, gap_aware {:.2}",
        f.staleness.mean(), s.staleness.mean(), ga.staleness.mean());
    Ok(())
}
