//! Quickstart: train the paper's MNIST MLP with FASGD and SASGD on a small
//! async cluster and compare validation-cost curves.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Everything here goes through the full three-layer stack: the gradient is
//! the AOT-lowered JAX graph (with the Pallas dense kernel inside) executed
//! via PJRT from the rust coordinator.

use fasgd::config::{ExperimentConfig, Policy};
use fasgd::experiments::common::run_experiment;

fn main() -> anyhow::Result<()> {
    fasgd::util::logging::init();

    let mut base = ExperimentConfig::default();
    base.clients = 16; // λ
    base.batch = 8; // µ
    base.iters = 4_000;
    base.eval_every = 250;

    let mut rows = Vec::new();
    for (policy, alpha) in [(Policy::Fasgd, 0.005f32), (Policy::Sasgd, 0.04)] {
        let mut cfg = base.clone();
        cfg.policy = policy;
        cfg.alpha = alpha;
        cfg.name = format!("quickstart-{}", policy.name());
        let summary = run_experiment(&cfg)?;

        println!("\n== {} (alpha={alpha}) ==", policy.name());
        println!("iter      val_cost   val_acc");
        for p in &summary.history.evals {
            println!("{:>6}    {:>8.4}   {:>6.3}", p.iter, p.val_loss, p.val_acc);
        }
        rows.push((policy, summary));
    }

    let (f, s) = (&rows[0].1, &rows[1].1);
    println!("\nfinal validation cost: FASGD {:.4} vs SASGD {:.4}  ({})",
        f.history.tail_mean(3),
        s.history.tail_mean(3),
        if f.history.tail_mean(3) < s.history.tail_mean(3) {
            "FASGD wins — the paper's Figure 1 claim"
        } else {
            "SASGD wins — unexpected at these settings"
        }
    );
    println!("mean step-staleness: FASGD {:.2}, SASGD {:.2}",
        f.staleness.mean(), s.staleness.mean());
    Ok(())
}
