//! End-to-end driver: asynchronously train a decoder-only transformer
//! char-LM through the full stack — rust dispatcher → PJRT → AOT-lowered
//! JAX graph → Pallas dense kernels — with the FASGD server policy, and log
//! the loss curve (recorded in EXPERIMENTS.md §E2E).
//!
//! ```text
//! make artifacts && cargo run --release --example e2e_transformer
//! # knobs: E2E_ITERS=600 E2E_CLIENTS=8 cargo run --release --example e2e_transformer
//! ```
//!
//! The model is the `e2e` config (~0.9M params; `python/compile/transformer
//! .py` also defines the ~110M `large` config which lowers identically but
//! is not compiled on this CPU-only image — DESIGN.md §5). The corpus is a
//! deterministic order-2 Markov stream, so the achievable NLL is well below
//! the ln(128)≈4.85 uniform floor; watching the curve fall proves all three
//! layers compose.

use fasgd::config::{ExperimentConfig, ModelKind, Policy};
use fasgd::experiments::common::run_experiment;

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    fasgd::util::logging::init();

    let mut cfg = ExperimentConfig::default();
    cfg.name = "e2e-transformer".into();
    cfg.model = ModelKind::TransformerE2e;
    cfg.policy = Policy::Fasgd;
    cfg.clients = env_u64("E2E_CLIENTS", 4) as usize;
    cfg.batch = 8; // fixed by the AOT artifact
    cfg.iters = env_u64("E2E_ITERS", 400);
    // FASGD's v-normalized steps are aggressive; 0.003 is stable for this
    // init (0.02 overshoots in the first ~50 iterations, then recovers).
    cfg.alpha = 0.003;
    cfg.eval_every = 25;
    cfg.log_every = 50;

    println!(
        "e2e: transformer_e2e (~0.9M params), lambda={}, {} iterations, FASGD",
        cfg.clients, cfg.iters
    );
    let summary = run_experiment(&cfg)?;

    println!("\niter      val_nll    val_acc");
    for p in &summary.history.evals {
        println!("{:>6}    {:>8.4}   {:>6.3}", p.iter, p.val_loss, p.val_acc);
    }
    let first = summary.history.evals.first().unwrap().val_loss;
    let last = summary.history.tail_mean(2);
    println!(
        "\nvalidation NLL: {first:.4} -> {last:.4} (uniform floor ln(128)={:.3})",
        (128f64).ln()
    );
    println!(
        "mean staleness {:.2}, server updates {}, wall {:.1}s",
        summary.staleness.mean(),
        summary.server_updates,
        summary.wall_secs
    );
    anyhow::ensure!(last < first, "E2E loss did not decrease");
    println!("E2E OK: all three layers compose and the model learns.");

    let out = std::path::Path::new("results");
    fasgd::metrics::writer::write_curves_csv(
        &out.join("e2e_transformer_curve.csv"),
        std::slice::from_ref(&summary),
    )?;
    println!("curve written to results/e2e_transformer_curve.csv");
    Ok(())
}
