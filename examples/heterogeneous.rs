//! Heterogeneous cluster scenario (the paper's closing motivation: "when
//! the training cluster is large and heterogeneous, we expect FASGD to
//! outperform SASGD even more").
//!
//! Two cluster shapes at the same λ:
//! * log-normal client speeds (persistently fast/slow machines) — the
//!   staleness distribution grows a heavy tail;
//! * cooldown dynamics (every selection temporarily suppresses a client,
//!   modelling compute time between pushes).
//!
//! ```text
//! make artifacts && cargo run --release --example heterogeneous
//! ```

use fasgd::config::{ExperimentConfig, Policy, SelectionRule};
use fasgd::experiments::common::run_experiment;
use fasgd::metrics::writer::render_table;

fn main() -> anyhow::Result<()> {
    fasgd::util::logging::init();

    let mut base = ExperimentConfig::default();
    base.clients = 32;
    base.batch = 4;
    base.iters = std::env::var("ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4_000);
    base.eval_every = 500;

    let shapes: [(&str, SelectionRule); 3] = [
        ("uniform", SelectionRule::Uniform),
        ("heterogeneous(sigma=1.5)", SelectionRule::Heterogeneous { sigma: 1.5 }),
        ("cooldown(0.2, 1.1)", SelectionRule::Cooldown { factor: 0.2, recovery: 1.1 }),
    ];

    let mut rows = Vec::new();
    for (label, rule) in shapes {
        let mut costs = Vec::new();
        let mut taus = Vec::new();
        for (policy, alpha) in [(Policy::Fasgd, 0.005f32), (Policy::Sasgd, 0.04)] {
            let mut cfg = base.clone();
            cfg.policy = policy.clone();
            cfg.alpha = alpha;
            cfg.selection = rule.clone();
            cfg.name = format!("hetero-{label}-{}", policy.name());
            let s = run_experiment(&cfg)?;
            costs.push(s.history.tail_mean(3));
            taus.push((s.staleness.mean(), s.staleness.max()));
        }
        rows.push(vec![
            label.to_string(),
            format!("{:.4}", costs[0]),
            format!("{:.4}", costs[1]),
            format!("{:+.4}", costs[1] - costs[0]),
            format!("{:.1}/{}", taus[0].0, taus[0].1),
        ]);
    }

    println!(
        "{}",
        render_table(
            &["cluster", "FASGD cost", "SASGD cost", "gap", "tau mean/max"],
            &rows
        )
    );
    println!(
        "paper expectation: the FASGD advantage (positive gap) persists or \
         grows as the staleness distribution becomes heavier-tailed."
    );
    Ok(())
}
