//! λ-scaling scenario (Figure 2 at example scale): how does the FASGD vs
//! SASGD gap evolve as the cluster grows and gradients get staler?
//!
//! ```text
//! make artifacts && cargo run --release --example lambda_scaling
//! # LAMBDAS=250,500,1000 ITERS=6000 cargo run --release --example lambda_scaling
//! ```

use fasgd::config::ExperimentConfig;
use fasgd::experiments::fig2;

fn main() -> anyhow::Result<()> {
    fasgd::util::logging::init();

    let lambdas: Vec<usize> = std::env::var("LAMBDAS")
        .unwrap_or_else(|_| "32,128,512".into())
        .split(',')
        .map(|s| s.trim().parse().expect("LAMBDAS"))
        .collect();
    let iters: u64 = std::env::var("ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4_000);

    let mut base = ExperimentConfig::default();
    base.iters = iters;
    base.eval_every = 500;
    // µ=128 in the paper; smaller here keeps the example snappy. Override
    // with the fig2 harness (`repro fig2`) for the paper's exact setting.
    base.batch = 16;

    let results = fig2::run(&base, &lambdas)?;
    fig2::report(&results, std::path::Path::new("results"))?;

    println!("paper claim: the gap (SASGD − FASGD cost) grows with lambda.");
    let gaps: Vec<f64> = results.iter().map(|r| r.gap()).collect();
    println!("measured gaps: {gaps:?}");
    Ok(())
}
