//! B-FASGD bandwidth tuning (Figure 3 at example scale): sweep the fetch
//! gate's `c` value and watch bandwidth drop while convergence holds; then
//! try the same on the push side and watch it hurt.
//!
//! ```text
//! make artifacts && cargo run --release --example bandwidth_tuning
//! # CS=0,0.1,0.5,2.0 ITERS=8000 cargo run --release --example bandwidth_tuning
//! ```

use fasgd::config::ExperimentConfig;
use fasgd::experiments::fig3;

fn main() -> anyhow::Result<()> {
    fasgd::util::logging::init();

    let cs: Vec<f64> = std::env::var("CS")
        .unwrap_or_else(|_| "0,0.05,0.2,1.0".into())
        .split(',')
        .map(|s| s.trim().parse().expect("CS"))
        .collect();
    let iters: u64 = std::env::var("ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5_000);

    let mut base = ExperimentConfig::default();
    base.iters = iters;
    base.clients = 16;
    base.batch = 8;
    base.eval_every = 500;

    let results = fig3::run(&base, &cs)?;
    fig3::report(&results, std::path::Path::new("results"))?;

    // Also demonstrate the Dean'12 fixed-period baseline for contrast.
    println!("\nDean'12 fixed-period baseline (k_fetch = 10):");
    let mut fixed = base.clone();
    fixed.name = "fixed-kfetch10".into();
    fixed.policy = fasgd::config::Policy::Fasgd;
    fixed.alpha = fasgd::experiments::fig1::FASGD_LR;
    fixed.bandwidth = fasgd::config::BandwidthMode::Fixed { k_push: 1, k_fetch: 10 };
    let run = fasgd::experiments::common::run_experiment(&fixed)?;
    println!(
        "  final cost {:.4}, fetch copies/potential {:.3}, total reduction {:.2}x",
        run.history.tail_mean(3),
        run.bandwidth.fetch_ratio(),
        run.bandwidth.reduction_factor()
    );
    println!(
        "  (B-FASGD achieves its reduction adaptively — heavy traffic early \
         when v is high, sparse later — the fixed baseline cannot.)"
    );
    Ok(())
}
