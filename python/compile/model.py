"""Layer-2 model: the paper's MLP (784 -> 200 relu -> 10, NLL cost).

Flat-parameter convention (DESIGN.md §3): every exported graph takes the
parameters as a single ``f32[P]`` vector. The layout is fixed and recorded in
the artifact metadata so the rust coordinator can treat the model as an
opaque flat vector:

    [w1 (784*200) | b1 (200) | w2 (200*10) | b2 (10)]   row-major

The dense layers call the Layer-1 Pallas kernel (``kernels.dense.dense_vjp``)
so the AOT-lowered gradient graph contains the kernel in both the forward and
backward directions. ``use_pallas=False`` swaps in the pure-jnp oracle
(used by tests to isolate kernel bugs from model bugs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.dense import dense_vjp

# The paper's architecture: 2-layer MLP, 200 hidden units, relu, NLL.
DEFAULT_SIZES = (784, 200, 10)


def param_layout(sizes=DEFAULT_SIZES):
    """The (name, shape) layout of the flat parameter vector, in order."""
    layout = []
    for i, (fan_in, fan_out) in enumerate(zip(sizes[:-1], sizes[1:])):
        layout.append((f"w{i + 1}", (fan_in, fan_out)))
        layout.append((f"b{i + 1}", (fan_out,)))
    return layout


def param_count(sizes=DEFAULT_SIZES) -> int:
    return sum(int(np.prod(s)) for _, s in param_layout(sizes))


def init_params(seed: int, sizes=DEFAULT_SIZES) -> np.ndarray:
    """Deterministic Glorot-uniform init, returned as the flat f32 vector."""
    rng = np.random.default_rng(seed)
    chunks = []
    for name, shape in param_layout(sizes):
        if name.startswith("w"):
            fan_in, fan_out = shape
            limit = np.sqrt(6.0 / (fan_in + fan_out))
            chunks.append(
                rng.uniform(-limit, limit, size=shape).astype(np.float32)
            )
        else:
            chunks.append(np.zeros(shape, dtype=np.float32))
    return np.concatenate([c.reshape(-1) for c in chunks])


def unflatten(theta, sizes=DEFAULT_SIZES):
    """Slice the flat vector back into the (w, b) list. Trace-safe."""
    params = []
    off = 0
    for _, shape in param_layout(sizes):
        size = int(np.prod(shape))
        params.append(theta[off:off + size].reshape(shape))
        off += size
    return params


def mlp_logits(theta, x, sizes=DEFAULT_SIZES, use_pallas: bool = True):
    """Forward pass to logits. ``x`` is ``f32[mu, sizes[0]]``."""
    parts = unflatten(theta, sizes)
    layer = dense_vjp if use_pallas else (
        lambda x_, w_, b_, act: ref.dense_ref(x_, w_, b_, act)
    )
    h = x
    n_layers = len(sizes) - 1
    for i in range(n_layers):
        w, b = parts[2 * i], parts[2 * i + 1]
        act = "relu" if i < n_layers - 1 else "none"
        h = layer(h, w, b, act)
    return h


def nll(logits, y):
    """Mean negative log likelihood; ``y`` is ``i32[mu]`` class labels."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))


def mlp_loss(theta, x, y, sizes=DEFAULT_SIZES, use_pallas: bool = True):
    return nll(mlp_logits(theta, x, sizes, use_pallas), y)


@functools.partial(jax.jit, static_argnames=("sizes", "use_pallas"))
def mlp_grad(theta, x, y, sizes=DEFAULT_SIZES, use_pallas: bool = True):
    """The client-side graph: ``(theta, x, y) -> (loss, grad_flat)``."""
    loss, grad = jax.value_and_grad(mlp_loss)(theta, x, y, sizes, use_pallas)
    return loss, grad


@functools.partial(jax.jit, static_argnames=("sizes", "use_pallas"))
def mlp_eval(theta, x, y, sizes=DEFAULT_SIZES, use_pallas: bool = True):
    """The validation graph: ``(theta, x, y) -> (mean_nll, accuracy)``."""
    logits = mlp_logits(theta, x, sizes, use_pallas)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return nll(logits, y), acc
