"""Layer-2 model: decoder-only transformer char-LM for the E2E driver.

The paper predates transformers; this model exists because the environment's
end-to-end validation requires training a transformer through the full
Rust -> PJRT -> HLO stack. Same flat-parameter convention as ``model.py``.

All 2-D projections (QKV, attention output, both FF layers, the LM head) go
through the Layer-1 Pallas dense kernel; attention softmax/masking and
layer-norm stay plain jnp (their cost is negligible next to the matmuls and
keeping them un-bloated keeps the interpret-mode HLO manageable).

Configs (``CONFIGS``): ``tiny`` for tests, ``e2e`` (~0.9M params) for the
end-to-end example, ``large`` (~110M params, paper-scale per the environment
spec) which lowers identically but is not compiled by default on this
CPU-only image — see DESIGN.md §5.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.dense import dense_vjp


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


CONFIGS = {
    "tiny": TransformerConfig("tiny", vocab=64, d_model=64, n_layers=2,
                              n_heads=2, d_ff=128, seq_len=32),
    "e2e": TransformerConfig("e2e", vocab=128, d_model=128, n_layers=4,
                             n_heads=4, d_ff=512, seq_len=64),
    "large": TransformerConfig("large", vocab=32768, d_model=768, n_layers=12,
                               n_heads=12, d_ff=3072, seq_len=512),
}


def param_layout(cfg: TransformerConfig):
    """(name, shape) layout of the flat parameter vector, in order."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    layout = [("embed", (v, d)), ("pos_embed", (cfg.seq_len, d))]
    for i in range(cfg.n_layers):
        layout += [
            (f"l{i}.ln1_scale", (d,)), (f"l{i}.ln1_bias", (d,)),
            (f"l{i}.wqkv", (d, 3 * d)), (f"l{i}.bqkv", (3 * d,)),
            (f"l{i}.wo", (d, d)), (f"l{i}.bo", (d,)),
            (f"l{i}.ln2_scale", (d,)), (f"l{i}.ln2_bias", (d,)),
            (f"l{i}.wff1", (d, f)), (f"l{i}.bff1", (f,)),
            (f"l{i}.wff2", (f, d)), (f"l{i}.bff2", (d,)),
        ]
    layout += [("lnf_scale", (d,)), ("lnf_bias", (d,)), ("head", (d, v)),
               ("head_bias", (v,))]
    return layout


def param_count(cfg: TransformerConfig) -> int:
    return sum(int(np.prod(s)) for _, s in param_layout(cfg))


def init_params(seed: int, cfg: TransformerConfig) -> np.ndarray:
    """Deterministic init: scaled-normal weights, zero biases, unit ln."""
    rng = np.random.default_rng(seed)
    chunks = []
    for name, shape in param_layout(cfg):
        base = name.split(".")[-1]
        if base.startswith(("ln1_scale", "ln2_scale")) or name == "lnf_scale":
            chunks.append(np.ones(shape, dtype=np.float32))
        elif base.startswith("b") or "bias" in name:
            chunks.append(np.zeros(shape, dtype=np.float32))
        else:
            std = 0.02 if name in ("embed", "pos_embed") else (
                1.0 / np.sqrt(shape[0]))
            chunks.append(
                (rng.standard_normal(shape) * std).astype(np.float32))
    return np.concatenate([c.reshape(-1) for c in chunks])


def _unflatten(theta, cfg: TransformerConfig):
    out = {}
    off = 0
    for name, shape in param_layout(cfg):
        size = int(np.prod(shape))
        out[name] = theta[off:off + size].reshape(shape)
        off += size
    return out


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _proj(x2d, w, b, layer):
    """2-D projection through the Pallas dense kernel (no activation)."""
    return layer(x2d, w, b, "none")


def transformer_logits(theta, tokens, cfg: TransformerConfig,
                       use_pallas: bool = True):
    """Causal LM forward. ``tokens`` is ``i32[batch, seq]``."""
    p = _unflatten(theta, cfg)
    layer = dense_vjp if use_pallas else (
        lambda x_, w_, b_, act: ref.dense_ref(x_, w_, b_, act))
    bsz, seq = tokens.shape
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim

    x = p["embed"][tokens] + p["pos_embed"][None, :seq, :]
    mask = jnp.tril(jnp.ones((seq, seq), dtype=bool))

    for i in range(cfg.n_layers):
        pre = _layer_norm(x, p[f"l{i}.ln1_scale"], p[f"l{i}.ln1_bias"])
        qkv = _proj(pre.reshape(bsz * seq, d), p[f"l{i}.wqkv"],
                    p[f"l{i}.bqkv"], layer).reshape(bsz, seq, 3, h, hd)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        # [b, h, s, hd]
        q = q.transpose(0, 2, 1, 3) / np.sqrt(hd)
        k = k.transpose(0, 2, 1, 3)
        v = v.transpose(0, 2, 1, 3)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k)
        att = jnp.where(mask[None, None, :, :], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(bsz * seq, d)
        x = x + _proj(ctx, p[f"l{i}.wo"], p[f"l{i}.bo"],
                      layer).reshape(bsz, seq, d)

        pre = _layer_norm(x, p[f"l{i}.ln2_scale"], p[f"l{i}.ln2_bias"])
        ff = layer(pre.reshape(bsz * seq, d), p[f"l{i}.wff1"],
                   p[f"l{i}.bff1"], "relu")
        ff = _proj(ff, p[f"l{i}.wff2"], p[f"l{i}.bff2"], layer)
        x = x + ff.reshape(bsz, seq, d)

    x = _layer_norm(x, p["lnf_scale"], p["lnf_bias"])
    logits = _proj(x.reshape(bsz * seq, d), p["head"], p["head_bias"], layer)
    return logits.reshape(bsz, seq, cfg.vocab)


def lm_loss(theta, tokens, targets, cfg: TransformerConfig,
            use_pallas: bool = True):
    """Mean next-token NLL. ``targets`` is ``tokens`` shifted by the caller."""
    logits = transformer_logits(theta, tokens, cfg, use_pallas)
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, targets[:, :, None], axis=-1)
    return -jnp.mean(picked)


@functools.partial(jax.jit, static_argnames=("cfg", "use_pallas"))
def lm_grad(theta, tokens, targets, cfg: TransformerConfig,
            use_pallas: bool = True):
    """The exported client graph: ``(theta, tokens, targets) -> (loss, grad)``."""
    loss, grad = jax.value_and_grad(lm_loss)(theta, tokens, targets, cfg,
                                             use_pallas)
    return loss, grad


@functools.partial(jax.jit, static_argnames=("cfg", "use_pallas"))
def lm_eval(theta, tokens, targets, cfg: TransformerConfig,
            use_pallas: bool = True):
    """Validation graph: ``(theta, tokens, targets) -> (mean_nll, accuracy)``."""
    logits = transformer_logits(theta, tokens, cfg, use_pallas)
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, targets[:, :, None], axis=-1)
    acc = jnp.mean(
        (jnp.argmax(logits, axis=-1) == targets).astype(jnp.float32))
    return -jnp.mean(picked), acc
