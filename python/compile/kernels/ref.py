"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has an oracle here; ``python/tests``
asserts ``assert_allclose(kernel(...), ref(...))`` over a hypothesis-driven
sweep of shapes and dtypes. The oracles are also what the L2 model falls back
to when ``use_pallas=False`` (useful for debugging HLO size).
"""

from __future__ import annotations

import jax.numpy as jnp


def dense_ref(x, w, b, activation: str = "relu"):
    """Fused dense layer oracle: ``act(x @ w + b)``.

    Args:
        x: ``f[m, k]`` input activations.
        w: ``f[k, n]`` weights.
        b: ``f[n]`` bias.
        activation: ``"relu"`` or ``"none"``.
    """
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return y.astype(x.dtype)


def fasgd_stats_ref(n, b, v, g, *, gamma: float, beta: float, eps: float,
                    variant: str = "std"):
    """FASGD moving-average update oracle (paper eqs. 4-6).

    ``variant="std"`` tracks an EMA of the gradient standard deviation
    (the interpretation consistent with the paper's prose and eq. 9);
    ``variant="inverse"`` implements eq. 6 exactly as printed (EMA of
    ``1/std``). See DESIGN.md §5.
    """
    n2 = gamma * n + (1.0 - gamma) * jnp.square(g)
    b2 = gamma * b + (1.0 - gamma) * g
    # max(., 0) guards tiny negative variance from float cancellation.
    std = jnp.sqrt(jnp.maximum(n2 - jnp.square(b2), 0.0) + eps)
    if variant == "std":
        v2 = beta * v + (1.0 - beta) * std
    elif variant == "inverse":
        v2 = beta * v + (1.0 - beta) / std
    else:
        raise ValueError(f"unknown variant {variant!r}")
    return n2, b2, v2


def fasgd_apply_ref(theta, v, g, *, alpha_over_tau, v_floor: float):
    """FASGD weight update oracle (paper eqs. 7-8).

    ``theta' = theta - (alpha/tau) / max(v, v_floor) * g`` elementwise.
    ``alpha_over_tau`` is a scalar (the caller folds the staleness divide).
    """
    return theta - alpha_over_tau / jnp.maximum(v, v_floor) * g


def fasgd_fused_ref(theta, n, b, v, g, *, alpha_over_tau, gamma: float,
                    beta: float, eps: float, v_floor: float,
                    variant: str = "std"):
    """Oracle for the fused stats+apply kernel: eqs. 4-8 in one pass."""
    n2, b2, v2 = fasgd_stats_ref(n, b, v, g, gamma=gamma, beta=beta, eps=eps,
                                 variant=variant)
    theta2 = fasgd_apply_ref(theta, v2, g, alpha_over_tau=alpha_over_tau,
                             v_floor=v_floor)
    return theta2, n2, b2, v2
