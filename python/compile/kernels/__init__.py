"""Layer-1 Pallas kernels and their pure-jnp oracles."""

from . import dense, fasgd_update, ref  # noqa: F401
