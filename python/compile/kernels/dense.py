"""Layer-1 Pallas kernel: fused dense layer ``act(x @ w + b)``.

TPU mapping (DESIGN.md §4): the matmul is tiled MXU-style — the grid walks
``(m/bm, n/bn, k/bk)``; each grid step keeps one ``(bm, bn)`` f32 accumulator
block resident in VMEM while streaming ``(bm, bk)``/``(bk, bn)`` operand tiles
from HBM, and the bias + activation epilogue is fused into the final k-step so
the activation never round-trips to HBM.

On this CPU-only image the kernel must run with ``interpret=True`` (real TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute); the
tiling is therefore a *structural* optimization, validated numerically here
and costed analytically in DESIGN.md §9.

Shapes that do not divide the block sizes are zero-padded in the wrapper and
sliced back after the call — zero padding is exact for matmul+bias+relu.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default tile sizes. Multiples of the 128x128 MXU tile; sized so the
# paper's MLP layers (784x200, 200x10) and the transformer projections fit
# in one or two grid steps (every grid step is a while-loop iteration in the
# lowered HLO, and XLA cannot fuse across them — fewer, larger tiles win on
# both TPU (pipelining) and the CPU interpret path). VMEM at the defaults:
# x-tile 256*1024*4 = 1 MiB, w-tile 1024*256*4 = 1 MiB, acc 256*256*4
# = 0.25 MiB -> ~2.3 MiB resident, well under the 16 MiB budget (DESIGN §9).
BLOCK_M = 256
BLOCK_N = 256
BLOCK_K = 1024


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, k_steps: int,
                   activation: str):
    """One ``(bm, bn)`` output tile; grid dim 2 walks the k blocks."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(ki == k_steps - 1)
    def _epilogue():
        y = acc_ref[...] + b_ref[...].astype(jnp.float32)
        if activation == "relu":
            y = jnp.maximum(y, 0.0)
        o_ref[...] = y.astype(o_ref.dtype)


def _pad_to(a, multiples):
    pads = []
    for dim, mult in zip(a.shape, multiples):
        rem = (-dim) % mult
        pads.append((0, rem))
    if any(p[1] for p in pads):
        a = jnp.pad(a, pads)
    return a


@functools.partial(
    jax.jit, static_argnames=("activation", "block_m", "block_n", "block_k")
)
def dense(x, w, b, activation: str = "relu", *, block_m: int = BLOCK_M,
          block_n: int = BLOCK_N, block_k: int = BLOCK_K):
    """Fused ``act(x @ w + b)`` as a Pallas kernel.

    Args:
        x: ``f[m, k]`` activations.
        w: ``f[k, n]`` weights.
        b: ``f[n]`` bias.
        activation: ``"relu"`` or ``"none"``.
    Returns:
        ``f[m, n]`` with the dtype of ``x``.
    """
    if activation not in ("relu", "none"):
        raise ValueError(f"unknown activation {activation!r}")
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {x.shape} @ {w.shape}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"

    # Clamp blocks to the problem so tiny layers stay single-tile (keeps the
    # interpret-mode grid, and hence the emitted HLO, small).
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    xp = _pad_to(x, (bm, bk))
    wp = _pad_to(w, (bk, bn))
    bp = _pad_to(b, (bn,))
    mp, kp = xp.shape
    _, np_ = wp.shape
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(
            _matmul_kernel, k_steps=grid[2], activation=activation
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, ki: (i, ki)),
            pl.BlockSpec((bk, bn), lambda i, j, ki: (ki, j)),
            pl.BlockSpec((bn,), lambda i, j, ki: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, ki: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        # VMEM scratch: the f32 accumulator tile (the MXU accumulation
        # register file on real hardware; a numpy array under interpret).
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense_vjp(x, w, b, activation: str = "relu"):
    """``dense`` with a hand-written VJP (Pallas kernels are not autodiffable).

    The backward matmuls (``dx = dz @ w.T``, ``dw = x.T @ dz``) reuse the same
    Pallas matmul kernel, so the gradient path exercises L1 as well.
    """
    return dense(x, w, b, activation)


def _dense_fwd(x, w, b, activation):
    y = dense(x, w, b, activation)
    return y, (x, w, y)


def _dense_bwd(activation, res, dy):
    x, w, y = res
    if activation == "relu":
        # relu: z > 0  <=>  y > 0 (post-activation), so y is a valid mask.
        dz = jnp.where(y > 0, dy, 0.0).astype(dy.dtype)
    else:
        dz = dy
    zero_n = jnp.zeros((w.shape[0],), dtype=dz.dtype)
    zero_m = jnp.zeros((w.shape[1],), dtype=dz.dtype)
    dx = dense(dz, w.T, zero_n, "none")
    dw = dense(x.T, dz, zero_m, "none")
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


dense_vjp.defvjp(_dense_fwd, _dense_bwd)
