"""Layer-1 Pallas kernel: fused FASGD statistics + weight update (eqs. 4-8).

One pass over the flat parameter vector ``f32[P]`` computes

    n' = g*n + (1-g)*grad^2                (eq. 4)
    b' = g*b + (1-g)*grad                  (eq. 5)
    s  = sqrt(max(n' - b'^2, 0) + eps)
    v' = B*v + (1-B)*s                     (eq. 6, "std" variant; see DESIGN §5)
         B*v + (1-B)/s                     (eq. 6 literal, "inverse" variant)
    theta' = theta - (a/tau)/max(v', floor) * grad    (eqs. 7-8)

TPU mapping (DESIGN.md §4): pure-VPU elementwise work, blocked in
``BLOCK``-element tiles so each grid step keeps 6 live ``f32[BLOCK]`` operands
in VMEM (~1.5 MiB at the default block — far under the 16 MiB budget) while
streaming the rest from HBM. ``alpha/tau`` varies per server update, so it is
a runtime scalar input; gamma/beta/eps/floor are training-session constants
and are baked into the artifact.

interpret=True on this CPU image; see dense.py for why.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 65536


def _fasgd_kernel(aot_ref, theta_ref, n_ref, b_ref, v_ref, g_ref,
                  theta_o, n_o, b_o, v_o, *, gamma: float, beta: float,
                  eps: float, v_floor: float, variant: str):
    g = g_ref[...]
    n2 = gamma * n_ref[...] + (1.0 - gamma) * g * g
    b2 = gamma * b_ref[...] + (1.0 - gamma) * g
    std = jnp.sqrt(jnp.maximum(n2 - b2 * b2, 0.0) + eps)
    if variant == "std":
        v2 = beta * v_ref[...] + (1.0 - beta) * std
    else:  # "inverse": eq. 6 exactly as printed
        v2 = beta * v_ref[...] + (1.0 - beta) / std
    alpha_over_tau = aot_ref[0]
    theta_o[...] = theta_ref[...] - alpha_over_tau / jnp.maximum(v2, v_floor) * g
    n_o[...] = n2
    b_o[...] = b2
    v_o[...] = v2


@functools.partial(
    jax.jit,
    static_argnames=("gamma", "beta", "eps", "v_floor", "variant", "block"),
)
def fasgd_update(theta, n, b, v, g, alpha_over_tau, *, gamma: float = 0.95,
                 beta: float = 0.9, eps: float = 1e-8, v_floor: float = 1e-6,
                 variant: str = "std", block: int = BLOCK):
    """Fused FASGD server update over flat ``f32[P]`` state.

    Args:
        theta, n, b, v: server state vectors, all ``f32[P]``.
        g: the incoming (stale) gradient, ``f32[P]``.
        alpha_over_tau: scalar ``f32[1]`` — master lr already divided by the
            clamped step-staleness.
    Returns:
        ``(theta', n', b', v')``.
    """
    if variant not in ("std", "inverse"):
        raise ValueError(f"unknown variant {variant!r}")
    (p,) = theta.shape
    blk = min(block, p)
    pad = (-p) % blk
    if pad:
        # v pads with 1.0 so the padded lanes never divide by the floor;
        # padded theta/g are zero so the padded update is exactly zero.
        theta = jnp.pad(theta, (0, pad))
        n = jnp.pad(n, (0, pad))
        b = jnp.pad(b, (0, pad))
        v = jnp.pad(v, (0, pad), constant_values=1.0)
        g = jnp.pad(g, (0, pad))
    pp = p + pad
    grid = (pp // blk,)
    vec_spec = pl.BlockSpec((blk,), lambda i: (i,))
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))

    outs = pl.pallas_call(
        functools.partial(_fasgd_kernel, gamma=gamma, beta=beta, eps=eps,
                          v_floor=v_floor, variant=variant),
        grid=grid,
        in_specs=[scalar_spec, vec_spec, vec_spec, vec_spec, vec_spec,
                  vec_spec],
        out_specs=[vec_spec] * 4,
        out_shape=[jax.ShapeDtypeStruct((pp,), jnp.float32)] * 4,
        interpret=True,
    )(alpha_over_tau, theta, n, b, v, g)
    if pad:
        outs = [o[:p] for o in outs]
    return tuple(outs)
