"""AOT lowering: JAX (L2, calling L1 Pallas kernels) -> HLO text artifacts.

This is the only place Python touches the system; ``make artifacts`` runs it
once and the rust coordinator consumes the output directory forever after.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(what the published ``xla`` rust crate binds) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every ``<name>.hlo.txt`` ships a ``<name>.meta.json`` sidecar describing the
exact input/output signature so the rust loader can validate shapes before
compiling, plus deterministic ``*_init.bin`` (little-endian f32) initial
parameter vectors and a ``manifest.json`` index.

Usage:
    cd python && python -m compile.aot --out ../artifacts \
        [--mus 1,4,8,16,32,128] [--eval-batch 512] [--seed 42] \
        [--transformers tiny,e2e] [--skip-existing]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, transformer
from .kernels.fasgd_update import fasgd_update

F32 = "f32"
S32 = "s32"

# FASGD hyper-parameters baked into the update artifacts. The paper leaves
# gamma/beta unlabelled ("we did not tune"); these are the Graves'13
# RMSProp-style defaults recorded in DESIGN.md §5. The rust-native update
# engine uses the same constants (rust/src/server/fasgd.rs) and the two are
# cross-validated by rust/tests/runtime_roundtrip.rs.
FASGD_GAMMA = 0.95
FASGD_BETA = 0.9
FASGD_EPS = 1e-8
FASGD_V_FLOOR = 1e-6


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(name, shape, dtype):
    return {"name": name, "shape": list(shape), "dtype": dtype}


class Emitter:
    def __init__(self, out_dir: str, skip_existing: bool):
        self.out_dir = out_dir
        self.skip_existing = skip_existing
        self.manifest = []
        os.makedirs(out_dir, exist_ok=True)

    def _paths(self, name):
        return (os.path.join(self.out_dir, f"{name}.hlo.txt"),
                os.path.join(self.out_dir, f"{name}.meta.json"))

    def emit(self, name: str, fn, example_args, meta: dict):
        hlo_path, meta_path = self._paths(name)
        meta = dict(meta)
        meta["name"] = name
        meta["hlo"] = os.path.basename(hlo_path)
        if self.skip_existing and os.path.exists(hlo_path) \
                and os.path.exists(meta_path):
            print(f"  [skip] {name}")
            self.manifest.append(meta)
            return
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        with open(hlo_path, "w") as f:
            f.write(text)
        with open(meta_path, "w") as f:
            json.dump(meta, f, indent=2, sort_keys=True)
        self.manifest.append(meta)
        print(f"  [ok]   {name}: {len(text) / 1024:.0f} KiB hlo")

    def emit_bin(self, name: str, vec: np.ndarray, meta: dict):
        path = os.path.join(self.out_dir, f"{name}.bin")
        meta = dict(meta)
        meta["name"] = name
        meta["bin"] = os.path.basename(path)
        meta["len"] = int(vec.size)
        vec.astype("<f4").tofile(path)
        with open(os.path.join(self.out_dir, f"{name}.meta.json"), "w") as f:
            json.dump(meta, f, indent=2, sort_keys=True)
        self.manifest.append(meta)
        print(f"  [ok]   {name}: {vec.size} f32")

    def finish(self):
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump({"artifacts": self.manifest}, f, indent=2,
                      sort_keys=True)
        print(f"manifest: {len(self.manifest)} artifacts")


def emit_mlp(em: Emitter, mus, eval_batch: int, seed: int):
    sizes = model.DEFAULT_SIZES
    p = model.param_count(sizes)
    layout = [
        {"name": n, "shape": list(s)} for n, s in model.param_layout(sizes)
    ]

    em.emit_bin(
        "mlp_init",
        model.init_params(seed, sizes),
        {"kind": "init", "model": "mlp", "param_count": p, "seed": seed,
         "sizes": list(sizes), "layout": layout},
    )

    theta = jnp.zeros((p,), jnp.float32)
    for mu in mus:
        x = jnp.zeros((mu, sizes[0]), jnp.float32)
        y = jnp.zeros((mu,), jnp.int32)
        em.emit(
            f"mlp_grad_mu{mu}",
            lambda t, xx, yy: model.mlp_grad(t, xx, yy, sizes, True),
            (theta, x, y),
            {"kind": "grad", "model": "mlp", "param_count": p, "batch": mu,
             "inputs": [_spec("theta", (p,), F32),
                        _spec("x", (mu, sizes[0]), F32),
                        _spec("y", (mu,), S32)],
             "outputs": [_spec("loss", (), F32), _spec("grad", (p,), F32)]},
        )

    x = jnp.zeros((eval_batch, sizes[0]), jnp.float32)
    y = jnp.zeros((eval_batch,), jnp.int32)
    em.emit(
        f"mlp_eval_b{eval_batch}",
        lambda t, xx, yy: model.mlp_eval(t, xx, yy, sizes, True),
        (theta, x, y),
        {"kind": "eval", "model": "mlp", "param_count": p,
         "batch": eval_batch,
         "inputs": [_spec("theta", (p,), F32),
                    _spec("x", (eval_batch, sizes[0]), F32),
                    _spec("y", (eval_batch,), S32)],
         "outputs": [_spec("loss", (), F32), _spec("acc", (), F32)]},
    )
    return p


def emit_fasgd(em: Emitter, p: int, model_name: str):
    vecs = tuple(jnp.zeros((p,), jnp.float32) for _ in range(5))
    aot = jnp.zeros((1,), jnp.float32)
    for variant in ("std", "inverse"):
        em.emit(
            f"fasgd_update_p{p}_{variant}",
            lambda th, n, b, v, g, a, _v=variant: fasgd_update(
                th, n, b, v, g, a, gamma=FASGD_GAMMA, beta=FASGD_BETA,
                eps=FASGD_EPS, v_floor=FASGD_V_FLOOR, variant=_v),
            (*vecs, aot),
            {"kind": "fasgd_update", "model": model_name, "param_count": p,
             "variant": variant,
             "hparams": {"gamma": FASGD_GAMMA, "beta": FASGD_BETA,
                         "eps": FASGD_EPS, "v_floor": FASGD_V_FLOOR},
             "inputs": [_spec("theta", (p,), F32), _spec("n", (p,), F32),
                        _spec("b", (p,), F32), _spec("v", (p,), F32),
                        _spec("grad", (p,), F32),
                        _spec("alpha_over_tau", (1,), F32)],
             "outputs": [_spec("theta", (p,), F32), _spec("n", (p,), F32),
                         _spec("b", (p,), F32), _spec("v", (p,), F32)]},
        )


def emit_transformer(em: Emitter, cfg_name: str, batch: int, seed: int):
    cfg = transformer.CONFIGS[cfg_name]
    p = transformer.param_count(cfg)
    layout = [
        {"name": n, "shape": list(s)}
        for n, s in transformer.param_layout(cfg)
    ]
    em.emit_bin(
        f"transformer_{cfg.name}_init",
        transformer.init_params(seed, cfg),
        {"kind": "init", "model": f"transformer_{cfg.name}",
         "param_count": p, "seed": seed, "layout": layout,
         "config": dataclass_dict(cfg)},
    )
    theta = jnp.zeros((p,), jnp.float32)
    toks = jnp.zeros((batch, cfg.seq_len), jnp.int32)
    common = {"model": f"transformer_{cfg.name}", "param_count": p,
              "batch": batch, "config": dataclass_dict(cfg)}
    em.emit(
        f"transformer_{cfg.name}_grad_b{batch}",
        lambda t, xx, yy: transformer.lm_grad(t, xx, yy, cfg, True),
        (theta, toks, toks),
        {**common, "kind": "grad",
         "inputs": [_spec("theta", (p,), F32),
                    _spec("tokens", (batch, cfg.seq_len), S32),
                    _spec("targets", (batch, cfg.seq_len), S32)],
         "outputs": [_spec("loss", (), F32), _spec("grad", (p,), F32)]},
    )
    em.emit(
        f"transformer_{cfg.name}_eval_b{batch}",
        lambda t, xx, yy: transformer.lm_eval(t, xx, yy, cfg, True),
        (theta, toks, toks),
        {**common, "kind": "eval",
         "inputs": [_spec("theta", (p,), F32),
                    _spec("tokens", (batch, cfg.seq_len), S32),
                    _spec("targets", (batch, cfg.seq_len), S32)],
         "outputs": [_spec("loss", (), F32), _spec("acc", (), F32)]},
    )
    return p


def dataclass_dict(cfg):
    return {k: getattr(cfg, k) for k in
            ("name", "vocab", "d_model", "n_layers", "n_heads", "d_ff",
             "seq_len")}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--mus", default="1,2,4,8,16,32,128")
    ap.add_argument("--eval-batch", type=int, default=512)
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--transformers", default="tiny,e2e")
    ap.add_argument("--transformer-batch", type=int, default=8)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    em = Emitter(args.out, args.skip_existing)
    mus = [int(m) for m in args.mus.split(",") if m]

    print("== mlp ==")
    p_mlp = emit_mlp(em, mus, args.eval_batch, args.seed)
    print("== fasgd update ==")
    emit_fasgd(em, p_mlp, "mlp")
    for name in [t for t in args.transformers.split(",") if t]:
        print(f"== transformer {name} ==")
        p_t = emit_transformer(em, name, args.transformer_batch, args.seed)
        emit_fasgd(em, p_t, f"transformer_{name}")
    em.finish()


if __name__ == "__main__":
    main()
