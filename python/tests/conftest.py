import os
import sys

# Allow `pytest tests/` from python/ without installing the package.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
