"""L2 correctness: MLP model — shapes, gradient checks, pallas/jnp parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

SIZES = (12, 7, 5)  # small stand-in for (784, 200, 10); same structure


def _batch(rng, mu, d_in, classes):
    x = rng.standard_normal((mu, d_in)).astype(np.float32)
    y = rng.integers(0, classes, size=(mu,)).astype(np.int32)
    return x, y


def test_param_count_paper_architecture():
    # 784*200 + 200 + 200*10 + 10 from the paper's 2-layer, 200-unit MLP.
    assert model.param_count((784, 200, 10)) == 159010


def test_init_deterministic():
    a = model.init_params(7, SIZES)
    b = model.init_params(7, SIZES)
    c = model.init_params(8, SIZES)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.dtype == np.float32
    assert a.shape == (model.param_count(SIZES),)


def test_unflatten_roundtrip():
    theta = model.init_params(0, SIZES)
    parts = model.unflatten(jnp.asarray(theta), SIZES)
    flat = np.concatenate([np.asarray(p).reshape(-1) for p in parts])
    np.testing.assert_array_equal(flat, theta)


@pytest.mark.parametrize("mu", [1, 4, 32])
def test_grad_shapes_and_finiteness(mu):
    rng = np.random.default_rng(0)
    theta = jnp.asarray(model.init_params(0, SIZES))
    x, y = _batch(rng, mu, SIZES[0], SIZES[-1])
    loss, grad = model.mlp_grad(theta, x, y, SIZES, True)
    assert loss.shape == ()
    assert grad.shape == theta.shape
    assert np.isfinite(float(loss))
    assert np.all(np.isfinite(np.asarray(grad)))


def test_pallas_matches_jnp_path():
    """The kernel-backed model must agree with the oracle-backed model."""
    rng = np.random.default_rng(1)
    theta = jnp.asarray(model.init_params(3, SIZES))
    x, y = _batch(rng, 16, SIZES[0], SIZES[-1])
    lp, gp = model.mlp_grad(theta, x, y, SIZES, True)
    lr, gr = model.mlp_grad(theta, x, y, SIZES, False)
    np.testing.assert_allclose(lp, lr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(gp, gr, rtol=1e-4, atol=1e-5)


def test_grad_against_finite_differences():
    rng = np.random.default_rng(2)
    sizes = (4, 3, 2)
    theta = model.init_params(0, sizes) + 0.1
    x, y = _batch(rng, 8, sizes[0], sizes[-1])
    _, grad = model.mlp_grad(jnp.asarray(theta), x, y, sizes, True)
    grad = np.asarray(grad)
    eps = 1e-3
    idxs = rng.choice(theta.size, size=6, replace=False)
    for i in idxs:
        tp, tm = theta.copy(), theta.copy()
        tp[i] += eps
        tm[i] -= eps
        lp = float(model.mlp_loss(jnp.asarray(tp), x, y, sizes, False))
        lm = float(model.mlp_loss(jnp.asarray(tm), x, y, sizes, False))
        fd = (lp - lm) / (2 * eps)
        assert abs(fd - grad[i]) < 5e-3, f"param {i}: fd={fd} ad={grad[i]}"


def test_eval_accuracy_bounds():
    rng = np.random.default_rng(3)
    theta = jnp.asarray(model.init_params(0, SIZES))
    x, y = _batch(rng, 64, SIZES[0], SIZES[-1])
    loss, acc = model.mlp_eval(theta, x, y, SIZES, True)
    assert 0.0 <= float(acc) <= 1.0
    assert float(loss) > 0.0


def test_sgd_reduces_loss():
    """A few plain-SGD steps on a fixed batch must reduce the loss."""
    rng = np.random.default_rng(4)
    theta = jnp.asarray(model.init_params(0, SIZES))
    x, y = _batch(rng, 32, SIZES[0], SIZES[-1])
    l0, _ = model.mlp_grad(theta, x, y, SIZES, True)
    for _ in range(60):
        _, g = model.mlp_grad(theta, x, y, SIZES, True)
        theta = theta - 0.2 * g
    l1, _ = model.mlp_grad(theta, x, y, SIZES, True)
    assert float(l1) < float(l0) * 0.8
