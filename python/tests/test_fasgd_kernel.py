"""L1 correctness: fused FASGD update kernel vs oracle (paper eqs. 4-8)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fasgd_update import fasgd_update
from compile.kernels.ref import (fasgd_apply_ref, fasgd_fused_ref,
                                 fasgd_stats_ref)

settings.register_profile("kernels", deadline=None, max_examples=25)
settings.load_profile("kernels")

HP = dict(gamma=0.95, beta=0.9, eps=1e-8, v_floor=1e-6)


def _state(rng, p):
    theta = rng.standard_normal(p).astype(np.float32)
    n = np.abs(rng.standard_normal(p)).astype(np.float32)
    b = (rng.standard_normal(p) * 0.1).astype(np.float32)
    v = np.abs(rng.standard_normal(p)).astype(np.float32) + 0.05
    g = rng.standard_normal(p).astype(np.float32)
    return theta, n, b, v, g


@given(
    p=st.sampled_from([1, 7, 1000, 65536, 65537, 159010]),
    variant=st.sampled_from(["std", "inverse"]),
    aot=st.floats(1e-5, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_matches_ref(p, variant, aot, seed):
    rng = np.random.default_rng(seed)
    theta, n, b, v, g = _state(rng, p)
    got = fasgd_update(theta, n, b, v, g,
                       jnp.array([aot], jnp.float32), variant=variant, **HP)
    want = fasgd_fused_ref(theta, n, b, v, g, alpha_over_tau=aot,
                           variant=variant, **HP)
    # The inverse variant divides by std values as small as sqrt(eps)=1e-4,
    # which amplifies f32 reassociation differences ~1e4x; tolerances are
    # scaled accordingly.
    rtol, atol = (1e-4, 1e-5) if variant == "std" else (2e-3, 1e-4)
    for name, a, e in zip(("theta", "n", "b", "v"), got, want):
        np.testing.assert_allclose(a, e, rtol=rtol, atol=atol,
                                   err_msg=f"output {name}")


def test_stats_recurrence_fixed_point():
    """With a constant gradient, n -> g^2, b -> g, std -> sqrt(eps)."""
    p = 64
    g = np.full(p, 0.5, np.float32)
    n = np.zeros(p, np.float32)
    b = np.zeros(p, np.float32)
    v = np.zeros(p, np.float32)
    stats_hp = dict(gamma=HP["gamma"], beta=HP["beta"], eps=HP["eps"])
    for _ in range(400):
        n, b, v = fasgd_stats_ref(n, b, v, g, **stats_hp)
    np.testing.assert_allclose(n, 0.25, rtol=1e-3)
    np.testing.assert_allclose(b, 0.5, rtol=1e-3)
    # std of a constant gradient is ~0 -> v decays toward sqrt(eps)
    assert float(jnp.max(v)) < 1e-2


def test_apply_direction_and_scale():
    """Update moves against the gradient, scaled by 1/(v*tau)."""
    p = 16
    theta = np.zeros(p, np.float32)
    v = np.full(p, 2.0, np.float32)
    g = np.ones(p, np.float32)
    out = fasgd_apply_ref(theta, v, g, alpha_over_tau=0.1, v_floor=1e-6)
    np.testing.assert_allclose(out, -0.05, rtol=1e-6)


def test_v_floor_prevents_blowup():
    """Near-zero v must not produce a huge step (the floor engages)."""
    p = 8
    theta = np.zeros(p, np.float32)
    v = np.zeros(p, np.float32)
    g = np.ones(p, np.float32)
    out = fasgd_apply_ref(theta, v, g, alpha_over_tau=1e-3, v_floor=1e-2)
    np.testing.assert_allclose(out, -0.1, rtol=1e-5)


def test_variants_differ():
    """std and inverse variants must actually produce different v tracks."""
    rng = np.random.default_rng(3)
    theta, n, b, v, g = _state(rng, 128)
    aot = jnp.array([0.01], jnp.float32)
    out_std = fasgd_update(theta, n, b, v, g, aot, variant="std", **HP)
    out_inv = fasgd_update(theta, n, b, v, g, aot, variant="inverse", **HP)
    assert not np.allclose(out_std[3], out_inv[3])


def test_rejects_bad_variant():
    z = np.zeros(4, np.float32)
    with pytest.raises(ValueError):
        fasgd_update(z, z, z, z, z, jnp.array([0.1], jnp.float32),
                     variant="bogus", **HP)
