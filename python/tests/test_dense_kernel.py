"""L1 correctness: Pallas fused dense kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes (including non-block-multiple, degenerate m=1, and
MXU-tile-crossing sizes) and both activations; the VJP is checked against
jax autodiff of the oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.dense import dense, dense_vjp
from compile.kernels.ref import dense_ref

settings.register_profile("kernels", deadline=None, max_examples=25)
settings.load_profile("kernels")


def _rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


@given(
    m=st.sampled_from([1, 2, 3, 8, 32, 127, 128, 130]),
    k=st.sampled_from([1, 7, 64, 128, 200, 257]),
    n=st.sampled_from([1, 10, 64, 128, 200]),
    activation=st.sampled_from(["relu", "none"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matches_ref(m, k, n, activation, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, m, k), _rand(rng, k, n) * 0.2, _rand(rng, n)
    got = dense(x, w, b, activation)
    want = dense_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                     activation)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(1, 784, 200), (32, 784, 200),
                                   (128, 200, 10), (8, 128, 128)])
def test_dense_paper_shapes(shape):
    """The exact layer shapes the MLP artifacts use."""
    m, k, n = shape
    rng = np.random.default_rng(0)
    x, w, b = _rand(rng, m, k), _rand(rng, k, n) * 0.1, _rand(rng, n)
    np.testing.assert_allclose(
        dense(x, w, b, "relu"),
        dense_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), "relu"),
        rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("blocks", [(8, 8, 8), (16, 32, 8), (128, 128, 128)])
def test_dense_block_invariance(blocks):
    """Tiling must not change the numbers (beyond f32 reassociation)."""
    bm, bn, bk = blocks
    rng = np.random.default_rng(1)
    x, w, b = _rand(rng, 33, 50), _rand(rng, 50, 21) * 0.2, _rand(rng, 21)
    got = dense(x, w, b, "relu", block_m=bm, block_n=bn, block_k=bk)
    want = dense_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), "relu")
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(seed=st.integers(0, 2**31 - 1),
       activation=st.sampled_from(["relu", "none"]))
def test_dense_vjp_matches_autodiff(seed, activation):
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, 8, 16), _rand(rng, 16, 12) * 0.3, _rand(rng, 12)

    def f_kernel(x, w, b):
        return jnp.sum(dense_vjp(x, w, b, activation) ** 2)

    def f_ref(x, w, b):
        return jnp.sum(dense_ref(x, w, b, activation) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(gk, gr):
        np.testing.assert_allclose(a, e, rtol=1e-3, atol=1e-3)


def test_dense_zero_padding_exact():
    """Padded lanes must contribute exactly zero, not epsilon."""
    rng = np.random.default_rng(2)
    x, w, b = _rand(rng, 5, 9), _rand(rng, 9, 3), np.zeros(3, np.float32)
    got = dense(x, w, b, "none", block_m=4, block_n=4, block_k=4)
    want = x @ w
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_dense_rejects_bad_activation():
    x = np.zeros((2, 2), np.float32)
    with pytest.raises(ValueError):
        dense(x, x, np.zeros(2, np.float32), "tanh")
