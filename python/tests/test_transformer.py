"""L2 correctness: transformer char-LM (the E2E driver model)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import transformer

CFG = transformer.CONFIGS["tiny"]


def _tokens(rng, batch, cfg):
    toks = rng.integers(0, cfg.vocab, size=(batch, cfg.seq_len + 1))
    return (toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32))


def test_param_count_matches_layout():
    p = transformer.param_count(CFG)
    theta = transformer.init_params(0, CFG)
    assert theta.shape == (p,)
    assert theta.dtype == np.float32


def test_init_deterministic():
    a = transformer.init_params(5, CFG)
    b = transformer.init_params(5, CFG)
    np.testing.assert_array_equal(a, b)


def test_grad_shapes_and_finiteness():
    rng = np.random.default_rng(0)
    theta = jnp.asarray(transformer.init_params(0, CFG))
    toks, tgts = _tokens(rng, 2, CFG)
    loss, grad = transformer.lm_grad(theta, toks, tgts, CFG, True)
    assert grad.shape == theta.shape
    assert np.isfinite(float(loss))
    assert np.all(np.isfinite(np.asarray(grad)))


def test_initial_loss_near_uniform():
    """Fresh init should predict ~uniform: loss ~= ln(vocab)."""
    rng = np.random.default_rng(1)
    theta = jnp.asarray(transformer.init_params(0, CFG))
    toks, tgts = _tokens(rng, 4, CFG)
    loss, _ = transformer.lm_eval(theta, toks, tgts, CFG, True)
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


def test_pallas_matches_jnp_path():
    rng = np.random.default_rng(2)
    theta = jnp.asarray(transformer.init_params(0, CFG))
    toks, tgts = _tokens(rng, 2, CFG)
    lp, gp = transformer.lm_grad(theta, toks, tgts, CFG, True)
    lr, gr = transformer.lm_grad(theta, toks, tgts, CFG, False)
    np.testing.assert_allclose(lp, lr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gp, gr, rtol=2e-3, atol=2e-4)


def test_causality():
    """Changing a future token must not change past logits."""
    rng = np.random.default_rng(3)
    theta = jnp.asarray(transformer.init_params(0, CFG))
    toks, _ = _tokens(rng, 1, CFG)
    logits_a = transformer.transformer_logits(theta, toks, CFG, False)
    toks_b = toks.copy()
    toks_b[0, -1] = (toks_b[0, -1] + 1) % CFG.vocab
    logits_b = transformer.transformer_logits(theta, toks_b, CFG, False)
    np.testing.assert_allclose(logits_a[0, :-1], logits_b[0, :-1],
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(logits_a[0, -1], logits_b[0, -1])


def test_sgd_reduces_loss():
    rng = np.random.default_rng(4)
    theta = jnp.asarray(transformer.init_params(0, CFG))
    toks, tgts = _tokens(rng, 4, CFG)
    l0 = float(transformer.lm_loss(theta, toks, tgts, CFG, True))
    for _ in range(10):
        _, g = transformer.lm_grad(theta, toks, tgts, CFG, True)
        theta = theta - 0.5 * g
    l1 = float(transformer.lm_loss(theta, toks, tgts, CFG, True))
    assert l1 < l0


@pytest.mark.parametrize("name", ["tiny", "e2e", "large"])
def test_configs_well_formed(name):
    cfg = transformer.CONFIGS[name]
    assert cfg.d_model % cfg.n_heads == 0
    assert transformer.param_count(cfg) > 0


def test_large_config_is_paper_scale():
    """`large` must be ~100M params (the environment's E2E reference scale)."""
    p = transformer.param_count(transformer.CONFIGS["large"])
    assert 80e6 < p < 200e6, p
