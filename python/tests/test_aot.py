"""AOT path: lowered HLO text is well-formed and metadata is consistent."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_smoke():
    import jax

    def fn(x, y):
        return (jnp.matmul(x, y) + 1.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "ENTRY" in text
    assert "f32[2,2]" in text


def test_to_hlo_text_pallas_lowers_to_plain_hlo():
    """interpret=True pallas must not leave custom-calls in the HLO."""
    import jax
    from compile.kernels.dense import dense

    spec_x = jax.ShapeDtypeStruct((4, 8), jnp.float32)
    spec_w = jax.ShapeDtypeStruct((8, 4), jnp.float32)
    spec_b = jax.ShapeDtypeStruct((4,), jnp.float32)
    text = aot.to_hlo_text(
        jax.jit(lambda x, w, b: (dense(x, w, b, "relu"),)).lower(
            spec_x, spec_w, spec_b))
    assert "ENTRY" in text
    assert "mosaic" not in text.lower()


@pytest.mark.skipif(not os.path.isdir(ARTIFACTS),
                    reason="run `make artifacts` first")
class TestEmittedArtifacts:
    def _manifest(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            return json.load(f)["artifacts"]

    def test_manifest_complete(self):
        names = {m["name"] for m in self._manifest()}
        assert "mlp_init" in names
        assert "mlp_grad_mu128" in names
        assert any(n.startswith("fasgd_update_p159010") for n in names)

    def test_every_artifact_file_exists(self):
        for meta in self._manifest():
            fname = meta.get("hlo") or meta.get("bin")
            assert os.path.exists(os.path.join(ARTIFACTS, fname)), fname

    def test_meta_matches_model(self):
        for meta in self._manifest():
            if meta["name"] == "mlp_init":
                assert meta["param_count"] == model.param_count()
                vec = np.fromfile(
                    os.path.join(ARTIFACTS, meta["bin"]), dtype="<f4")
                assert vec.size == meta["param_count"]
                np.testing.assert_array_equal(
                    vec, model.init_params(meta["seed"]))

    def test_grad_meta_signature(self):
        for meta in self._manifest():
            if meta["kind"] == "grad" and meta["model"] == "mlp":
                p = meta["param_count"]
                mu = meta["batch"]
                ins = {i["name"]: i for i in meta["inputs"]}
                assert ins["theta"]["shape"] == [p]
                assert ins["x"]["shape"] == [mu, 784]
                assert ins["y"]["shape"] == [mu]
                outs = {o["name"]: o for o in meta["outputs"]}
                assert outs["grad"]["shape"] == [p]

    def test_hlo_files_parseable_header(self):
        for meta in self._manifest():
            if "hlo" not in meta:
                continue
            with open(os.path.join(ARTIFACTS, meta["hlo"])) as f:
                text = f.read()
            assert "ENTRY" in text, meta["name"]
            assert "custom-call" not in text.lower(), (
                f"{meta['name']}: CPU PJRT cannot run custom-calls")
